//! Mixed-radix Cooley–Tukey complex FFT over the factor set the paper's
//! autotuner searches (`2^a·3^b·5^c·7^d`, §3.4) — generic enough to take
//! any prime factor, but the planner routes large primes to Bluestein,
//! exactly as cuFFT does (paper §3.2).
//!
//! Recursive decimation-in-time with a shared root-of-unity table: the
//! sub-transform of size `n/s` reads twiddles at stride `s` in the global
//! table (`W_{n/s}^j = W_n^{j·s}`), so one table serves the whole tree.

use super::complex::C32;

/// Precomputed state for complex transforms of one size.
pub struct MixedRadix {
    n: usize,
    factors: Vec<usize>,
    /// `roots[j] = e^{-2πi j / n}` for the forward transform.
    roots: Vec<C32>,
}

/// Prime factorization, smallest first (2,3,5,7 prioritized, then any).
pub fn factorize(mut n: usize) -> Vec<usize> {
    let mut f = Vec::new();
    for p in [2usize, 3, 5, 7] {
        while n % p == 0 {
            f.push(p);
            n /= p;
        }
    }
    let mut p = 11;
    while p * p <= n {
        while n % p == 0 {
            f.push(p);
            n /= p;
        }
        p += 2;
    }
    if n > 1 {
        f.push(n);
    }
    f
}

impl MixedRadix {
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "transform size must be positive");
        let roots = (0..n).map(|j| C32::root_of_unity(j as i64, n)).collect();
        MixedRadix { n, factors: factorize(n), roots }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    #[inline]
    fn root(&self, idx: usize, inverse: bool) -> C32 {
        let w = self.roots[idx % self.n];
        if inverse {
            w.conj()
        } else {
            w
        }
    }

    /// Out-of-place transform. `inverse` applies the `+` sign convention
    /// but NOT the `1/n` scale (callers own normalization, like FFTW).
    pub fn transform(&self, input: &[C32], inverse: bool) -> Vec<C32> {
        assert_eq!(input.len(), self.n, "input length != plan size");
        let mut out = input.to_vec();
        if self.n.is_power_of_two() && self.n > 1 {
            // §Perf: iterative radix-2 fast path — the recursive generic
            // combine allocates per level and was the planner's top
            // bottleneck (EXPERIMENTS.md §Perf, fft-planner entry)
            self.pow2_in_place(&mut out, inverse);
            return out;
        }
        // general mixed-radix path with hoisted scratch (one allocation
        // per transform instead of one per recursion node); budget:
        // Σ_levels n_level ≤ 2n for the combine buffers plus 2·r per
        // level for the row temporaries
        let scratch_len =
            2 * self.n + 2 * self.factors.iter().sum::<usize>().max(1);
        let mut scratch = vec![C32::ZERO; scratch_len];
        out.fill(C32::ZERO);
        self.rec(input, 1, &mut out, self.n, 0, inverse, &mut scratch);
        out
    }

    /// Iterative radix-2 DIT with bit-reversal, twiddles from the shared
    /// root table at stride n/m.
    fn pow2_in_place(&self, buf: &mut [C32], inverse: bool) {
        let n = self.n;
        let log2n = n.trailing_zeros();
        for i in 0..n {
            let j = (i as u32).reverse_bits() >> (32 - log2n);
            let j = j as usize;
            if i < j {
                buf.swap(i, j);
            }
        }
        for s in 0..log2n {
            let half = 1usize << s;
            let m = half << 1;
            let stride = n / m;
            let mut base = 0;
            while base < n {
                for j in 0..half {
                    let w = self.root(j * stride, inverse);
                    let a = buf[base + j];
                    let b = buf[base + j + half] * w;
                    buf[base + j] = a + b;
                    buf[base + j + half] = a - b;
                }
                base += m;
            }
        }
    }

    /// In-place convenience over `transform`.
    pub fn transform_in_place(&self, buf: &mut [C32], inverse: bool) {
        let out = self.transform(buf, inverse);
        buf.copy_from_slice(&out);
    }

    /// Recursive DIT step: transform `n_cur` elements of `input` taken at
    /// `stride`, writing contiguously into `out`. `depth` indexes the
    /// factor list; the twiddle stride for this level is `self.n / n_cur`.
    /// `scratch` is the transform-wide workspace: `[0, n_cur)` holds this
    /// level's combine buffer, the tail holds the per-row temporaries and
    /// deeper levels' space (hoisted allocation, §Perf).
    #[allow(clippy::too_many_arguments)]
    fn rec(&self, input: &[C32], stride: usize, out: &mut [C32],
           n_cur: usize, depth: usize, inverse: bool,
           scratch: &mut [C32]) {
        if n_cur == 1 {
            out[0] = input[0];
            return;
        }
        let r = self.factors[depth];
        let m = n_cur / r;
        // sub-transforms: q-th takes elements q, q+r, q+2r, ... (×stride)
        {
            let (_, deeper) = scratch.split_at_mut(n_cur + 2 * r);
            for q in 0..r {
                let (head, tail) = out.split_at_mut(q * m);
                let _ = head;
                self.rec(&input[q * stride..], stride * r, &mut tail[..m],
                         m, depth + 1, inverse, deeper);
            }
        }
        // combine r groups with twiddles; ts converts local k to global
        let ts = self.n / n_cur;
        let (combine, rest) = scratch.split_at_mut(n_cur);
        let (t, row) = rest.split_at_mut(r);
        let row = &mut row[..r];
        for k1 in 0..m {
            for (q, tq) in t[..r].iter_mut().enumerate() {
                // W_{n_cur}^{q·k1} = roots[q·k1·ts]
                *tq = out[q * m + k1] * self.root(q * k1 * ts, inverse);
            }
            // small DFT of size r across the groups
            for (q2, rv) in row.iter_mut().enumerate() {
                let mut acc = t[0];
                for (q, tq) in t[..r].iter().enumerate().skip(1) {
                    // W_r^{q·q2} = roots[q·q2·(n/r)]
                    acc = acc.mul_add(*tq,
                                      self.root(q * q2 * (self.n / r),
                                                inverse));
                }
                *rv = acc;
            }
            for q2 in 0..r {
                combine[q2 * m + k1] = row[q2];
            }
        }
        out[..n_cur].copy_from_slice(combine);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::naive_dft;

    fn assert_close(a: &[C32], b: &[C32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert!((*x - *y).abs() < tol,
                    "idx {i}: {x:?} vs {y:?} (tol {tol})");
        }
    }

    fn rand_signal(n: usize, seed: u64) -> Vec<C32> {
        // xorshift — deterministic, no rand dep in unit tests
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        let mut next = move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            (s as f64 / u64::MAX as f64) as f32 * 2.0 - 1.0
        };
        (0..n).map(|_| C32::new(next(), next())).collect()
    }

    #[test]
    fn factorize_examples() {
        assert_eq!(factorize(1), vec![]);
        assert_eq!(factorize(8), vec![2, 2, 2]);
        assert_eq!(factorize(12), vec![2, 2, 3]);
        assert_eq!(factorize(105), vec![3, 5, 7]);
        assert_eq!(factorize(13), vec![13]);
        assert_eq!(factorize(22), vec![2, 11]);
    }

    #[test]
    fn matches_naive_on_smooth_sizes() {
        for n in [1usize, 2, 3, 4, 5, 6, 7, 8, 9, 12, 16, 20, 21, 24, 35,
                  36, 49, 64, 105, 128] {
            let x = rand_signal(n, n as u64);
            let plan = MixedRadix::new(n);
            let got = plan.transform(&x, false);
            let want = naive_dft(&x, false);
            let tol = 1e-4 * (n as f32).sqrt().max(1.0) * 4.0;
            assert_close(&got, &want, tol);
        }
    }

    #[test]
    fn matches_naive_with_odd_primes() {
        // generic combine handles primes outside {2,3,5,7} too
        for n in [11usize, 13, 22, 26] {
            let x = rand_signal(n, n as u64 + 99);
            let plan = MixedRadix::new(n);
            assert_close(&plan.transform(&x, false), &naive_dft(&x, false),
                         1e-3);
        }
    }

    #[test]
    fn round_trip() {
        for n in [8usize, 12, 30, 64] {
            let x = rand_signal(n, 7);
            let plan = MixedRadix::new(n);
            let fwd = plan.transform(&x, false);
            let mut back = plan.transform(&fwd, true);
            for c in back.iter_mut() {
                *c = c.scale(1.0 / n as f32);
            }
            assert_close(&back, &x, 1e-4);
        }
    }

    #[test]
    fn linearity() {
        let n = 24;
        let x = rand_signal(n, 1);
        let y = rand_signal(n, 2);
        let plan = MixedRadix::new(n);
        let sum: Vec<C32> = x.iter().zip(&y).map(|(a, b)| *a + *b).collect();
        let fx = plan.transform(&x, false);
        let fy = plan.transform(&y, false);
        let fsum = plan.transform(&sum, false);
        let want: Vec<C32> = fx.iter().zip(&fy).map(|(a, b)| *a + *b).collect();
        assert_close(&fsum, &want, 1e-3);
    }
}
