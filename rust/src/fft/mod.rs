//! From-scratch FFT substrate (the paper's cuFFT/fbfft dependency pair).
//!
//! Two personalities, mirroring the paper's two transform providers:
//!
//! * the **vendor-analogue** general-purpose planner ([`plan`]): arbitrary
//!   sizes via mixed-radix Cooley–Tukey over {2,3,5,7} ([`radix`]) with a
//!   Bluestein fallback for other factors ([`bluestein`]), real transforms
//!   ([`real`]) and row-column 2-D ([`fft2d`]). Like cuFFT it is a black
//!   box: callers materialize their own zero padding and layout changes.
//! * **[`fbfft_host`]** — the batched small-transform specialist
//!   reproducing the paper's §5 design points on this testbed: sizes
//!   8–256, implicit zero-copy padding, fused transposed output, batch
//!   panel blocking, per-size cached twiddle/bit-reversal tables — with
//!   the [`soa`] split-complex batch-lane kernels underneath (batch
//!   mapped across SIMD lanes, the CPU image of the §5 warp mapping).
//!
//! Everything is `f32` (the paper is single-precision throughout);
//! correctness tests compare against an `f64` naive DFT.

pub mod bluestein;
pub mod complex;
pub mod dif;
pub mod fbfft_host;
pub mod fft2d;
pub mod plan;
pub mod radix;
pub mod real;
pub mod soa;

pub use complex::C32;
pub use plan::{Direction, Plan};

/// Smallest power of two `>= n`.
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

/// `true` iff `n` factorizes over the radix set {2,3,5,7} the planner's
/// Cooley–Tukey path supports (the paper's autotuner searches exactly the
/// sizes `2^a·3^b·5^c·7^d`, §3.4).
pub fn is_smooth(mut n: usize) -> bool {
    if n == 0 {
        return false;
    }
    for p in [2, 3, 5, 7] {
        while n % p == 0 {
            n /= p;
        }
    }
    n == 1
}

/// Naive `O(n²)` DFT in f64, used by this module's own tests. Forward
/// sign convention `e^{-2πi jk/n}`, unnormalized inverse. The
/// conformance layer keeps its own definition
/// ([`crate::testkit::oracle::dft64`]) so the oracle stays independent
/// of the substrate it checks.
pub fn naive_dft(input: &[C32], inverse: bool) -> Vec<C32> {
    let n = input.len();
    let sign = if inverse { 2.0 } else { -2.0 };
    let mut out = Vec::with_capacity(n);
    for k in 0..n {
        let (mut re, mut im) = (0f64, 0f64);
        for (j, x) in input.iter().enumerate() {
            let ang = sign * std::f64::consts::PI * (j as f64) * (k as f64)
                / (n as f64);
            let (s, c) = ang.sin_cos();
            re += x.re as f64 * c - x.im as f64 * s;
            im += x.re as f64 * s + x.im as f64 * c;
        }
        out.push(C32::new(re as f32, im as f32));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn next_pow2_basics() {
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(13), 16);
        assert_eq!(next_pow2(16), 16);
        assert_eq!(next_pow2(57), 64);
    }

    #[test]
    fn smooth_sizes() {
        for n in [1, 2, 8, 12, 14, 15, 21, 35, 105, 128, 210] {
            assert!(is_smooth(n), "{n} should be smooth");
        }
        for n in [11, 13, 22, 26, 121] {
            assert!(!is_smooth(n), "{n} should not be smooth");
        }
    }

    #[test]
    fn naive_dft_impulse_is_flat() {
        let mut x = vec![C32::ZERO; 8];
        x[0] = C32::new(1.0, 0.0);
        for c in naive_dft(&x, false) {
            assert!((c.re - 1.0).abs() < 1e-6 && c.im.abs() < 1e-6);
        }
    }
}
