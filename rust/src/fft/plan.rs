//! The vendor-analogue FFT planner: algorithm selection + plan cache.
//!
//! Mirrors the cuFFT behaviour the paper reacts to (§3.2): smooth sizes
//! (`2^a·3^b·5^c·7^d`) run mixed-radix Cooley–Tukey; anything else pays
//! for Bluestein. Plans are cached per size like `cufftPlan` handles —
//! including the cached plans' memory footprint being a real cost, which
//! the paper calls out ('additional temporary memory is reserved by each
//! cufftPlan', §6).

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::bluestein::Bluestein;
use super::complex::C32;
use super::is_smooth;
use super::radix::MixedRadix;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    Forward,
    Inverse,
}

enum Algo {
    MixedRadix(MixedRadix),
    Bluestein(Bluestein),
}

/// A complex-to-complex plan for one size.
pub struct Plan {
    n: usize,
    algo: Algo,
}

impl Plan {
    pub fn new(n: usize) -> Self {
        let algo = if is_smooth(n) {
            Algo::MixedRadix(MixedRadix::new(n))
        } else {
            Algo::Bluestein(Bluestein::new(n))
        };
        Plan { n, algo }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn algorithm_name(&self) -> &'static str {
        match self.algo {
            Algo::MixedRadix(_) => "mixed-radix",
            Algo::Bluestein(_) => "bluestein",
        }
    }

    /// Unnormalized transform (inverse carries no 1/n, as in FFTW/cuFFT).
    pub fn transform(&self, input: &[C32], dir: Direction) -> Vec<C32> {
        let inverse = dir == Direction::Inverse;
        match &self.algo {
            Algo::MixedRadix(p) => p.transform(input, inverse),
            Algo::Bluestein(p) => p.transform(input, inverse),
        }
    }

    /// Normalized inverse (divides by n).
    pub fn inverse_normalized(&self, input: &[C32]) -> Vec<C32> {
        let mut out = self.transform(input, Direction::Inverse);
        let s = 1.0 / self.n as f32;
        for c in out.iter_mut() {
            *c = c.scale(s);
        }
        out
    }
}

/// Process-wide plan cache (the `cufftPlan` analogue).
pub fn cached(n: usize) -> Arc<Plan> {
    static CACHE: OnceLock<Mutex<HashMap<usize, Arc<Plan>>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let mut guard = cache.lock().expect("plan cache poisoned");
    guard.entry(n).or_insert_with(|| Arc::new(Plan::new(n))).clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::naive_dft;

    #[test]
    fn picks_algorithms_like_cufft() {
        assert_eq!(Plan::new(128).algorithm_name(), "mixed-radix");
        assert_eq!(Plan::new(105).algorithm_name(), "mixed-radix");
        assert_eq!(Plan::new(11).algorithm_name(), "bluestein");
        assert_eq!(Plan::new(26).algorithm_name(), "bluestein");
    }

    #[test]
    fn both_paths_agree_with_naive() {
        for n in [12usize, 13] {
            let x: Vec<C32> = (0..n)
                .map(|j| C32::new(j as f32 * 0.3 - 1.0, (j as f32).cos()))
                .collect();
            let plan = Plan::new(n);
            let got = plan.transform(&x, Direction::Forward);
            let want = naive_dft(&x, false);
            for (g, w) in got.iter().zip(&want) {
                assert!((*g - *w).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn cache_returns_same_plan() {
        let a = cached(48);
        let b = cached(48);
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(a.len(), 48);
    }

    #[test]
    fn normalized_inverse_round_trips() {
        let n = 20;
        let x: Vec<C32> =
            (0..n).map(|j| C32::new((j as f32).sin(), 0.25 * j as f32)).collect();
        let plan = Plan::new(n);
        let f = plan.transform(&x, Direction::Forward);
        let back = plan.inverse_normalized(&f);
        for (b, o) in back.iter().zip(&x) {
            assert!((*b - *o).abs() < 1e-4);
        }
    }
}
