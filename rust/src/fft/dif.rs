//! Decimation-in-frequency / decimation-in-time pair with **bit-reversal
//! elision** — the optimization the paper plans in §5.1/§6 and derives in
//! the Supplement (§8.2):
//!
//! > "bit reversal portions can be eliminated with the FFT using
//! >  *decimation in frequency* (DIF) and the IFFT with *decimation in
//! >  time* (DIT)"
//!
//! A DIF forward transform consumes natural-order input and produces
//! **bit-reversed** output *without* a permutation pass; a DIT inverse
//! consumes bit-reversed input and produces natural-order output, again
//! permutation-free. The frequency-domain stage between them (the conv
//! pipeline's pointwise CGEMM) is order-agnostic — every bin is
//! independent — so the two permutations cancel out of the whole
//! pipeline and are simply never executed.
//!
//! This module provides the C2C core on the same cached-plan machinery
//! as `fbfft_host`; `benches/ablation.rs` measures the saving.

use super::complex::C32;
use super::fbfft_host::FbfftPlan;

impl FbfftPlan {
    /// Forward DIF butterfly pass: natural-order input → bit-reversed
    /// output, NO permutation. Stages run large-to-small (the mirror
    /// image of DIT), twiddles applied on the way out of each butterfly.
    pub fn cfft_dif_bitrev_out(&self, buf: &mut [C32], inverse: bool) {
        let n = self.len();
        debug_assert_eq!(buf.len(), n);
        let log2n = n.trailing_zeros();
        // twiddle layout in the shared LUT: stage s (DIT numbering) has
        // half-block 2^s at offset 2^s - 1; DIF walks it backwards.
        for s in (0..log2n).rev() {
            let half = 1usize << s;
            let m = half << 1;
            let tw_off = half - 1;
            let mut base = 0;
            while base < n {
                for j in 0..half {
                    let w = self.twiddle(tw_off + j, inverse);
                    let a = buf[base + j];
                    let b = buf[base + j + half];
                    buf[base + j] = a + b;
                    buf[base + j + half] = (a - b) * w;
                }
                base += m;
            }
        }
    }

    /// Inverse DIT butterfly pass: bit-reversed input → natural-order
    /// output, NO permutation (the bit reversal DIT normally performs up
    /// front is exactly the order `cfft_dif_bitrev_out` left the data in).
    /// Unnormalized, like the planner's inverse.
    pub fn cfft_dit_bitrev_in(&self, buf: &mut [C32], inverse: bool) {
        let n = self.len();
        debug_assert_eq!(buf.len(), n);
        let log2n = n.trailing_zeros();
        for s in 0..log2n {
            let half = 1usize << s;
            let m = half << 1;
            let tw_off = half - 1;
            let mut base = 0;
            while base < n {
                for j in 0..half {
                    let w = self.twiddle(tw_off + j, inverse);
                    let a = buf[base + j];
                    let b = buf[base + j + half] * w;
                    buf[base + j] = a + b;
                    buf[base + j + half] = a - b;
                }
                base += m;
            }
        }
    }

    /// The §8.2 round trip: DIF forward, pointwise work in bit-reversed
    /// order, DIT inverse — zero permutations end to end. Returns the
    /// normalized identity for testing/benching.
    pub fn round_trip_no_bitrev(&self, buf: &mut [C32]) {
        self.cfft_dif_bitrev_out(buf, false);
        // (frequency-domain pointwise stage would run here, bit-reversed)
        self.cfft_dit_bitrev_in(buf, true);
        let s = 1.0 / self.len() as f32;
        for c in buf.iter_mut() {
            *c = c.scale(s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::fbfft_host;
    use crate::fft::naive_dft;
    use crate::util::Rng;

    fn rand_signal(n: usize, seed: u64) -> Vec<C32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| C32::new(rng.normal(), rng.normal())).collect()
    }

    fn bitrev_perm(n: usize) -> Vec<usize> {
        let lg = n.trailing_zeros();
        (0..n).map(|i| ((i as u32).reverse_bits() >> (32 - lg)) as usize)
            .collect()
    }

    #[test]
    fn dif_output_is_bitreversed_dft() {
        for n in [8usize, 16, 32, 64] {
            let x = rand_signal(n, n as u64);
            let plan = fbfft_host::cached(n);
            let mut buf = x.clone();
            plan.cfft_dif_bitrev_out(&mut buf, false);
            let want = naive_dft(&x, false);
            let perm = bitrev_perm(n);
            for (i, &p) in perm.iter().enumerate() {
                assert!((buf[i] - want[p]).abs() < 1e-3 * (n as f32).sqrt(),
                        "n={n} i={i}");
            }
        }
    }

    #[test]
    fn dit_consumes_bitreversed_spectrum() {
        for n in [8usize, 16, 32] {
            let x = rand_signal(n, 100 + n as u64);
            let want = naive_dft(&x, false);
            let perm = bitrev_perm(n);
            // hand the DIT inverse a bit-reversed spectrum
            let mut buf = vec![C32::ZERO; n];
            for (i, &p) in perm.iter().enumerate() {
                buf[i] = want[p];
            }
            let plan = fbfft_host::cached(n);
            plan.cfft_dit_bitrev_in(&mut buf, true);
            for (b, o) in buf.iter().zip(&x) {
                let b = b.scale(1.0 / n as f32);
                assert!((b - *o).abs() < 1e-3, "n={n}");
            }
        }
    }

    #[test]
    fn round_trip_without_any_permutation() {
        for n in [8usize, 64, 256] {
            let x = rand_signal(n, 7 * n as u64);
            let plan = fbfft_host::cached(n);
            let mut buf = x.clone();
            plan.round_trip_no_bitrev(&mut buf);
            for (b, o) in buf.iter().zip(&x) {
                assert!((*b - *o).abs() < 1e-3, "n={n}");
            }
        }
    }

    #[test]
    fn pointwise_product_in_bitreversed_order_is_convolution() {
        // the actual §8.2 claim: circular convolution works entirely in
        // bit-reversed frequency order
        let n = 16usize;
        let a = rand_signal(n, 1);
        let b = rand_signal(n, 2);
        let plan = fbfft_host::cached(n);
        let (mut fa, mut fb) = (a.clone(), b.clone());
        plan.cfft_dif_bitrev_out(&mut fa, false);
        plan.cfft_dif_bitrev_out(&mut fb, false);
        let mut prod: Vec<C32> =
            fa.iter().zip(&fb).map(|(x, y)| *x * *y).collect();
        plan.cfft_dit_bitrev_in(&mut prod, true);
        // naive circular convolution
        for t in 0..n {
            let mut want = C32::ZERO;
            for j in 0..n {
                want += a[j] * b[(n + t - j) % n];
            }
            let got = prod[t].scale(1.0 / n as f32);
            assert!((got - want).abs() < 1e-2, "t={t}: {got:?} vs {want:?}");
        }
    }
}
