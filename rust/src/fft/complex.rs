//! Single-precision complex scalar, built from scratch (the substrate
//! rule: no external numerics crates on the hot path).

use std::ops::{Add, AddAssign, Mul, MulAssign, Neg, Sub, SubAssign};

/// `f32` complex number. `#[repr(C)]` so slices of `C32` can be viewed as
/// interleaved `[re, im]` `f32` pairs when crossing into PJRT literals.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
#[repr(C)]
pub struct C32 {
    pub re: f32,
    pub im: f32,
}

impl C32 {
    pub const ZERO: C32 = C32 { re: 0.0, im: 0.0 };
    pub const ONE: C32 = C32 { re: 1.0, im: 0.0 };

    #[inline(always)]
    pub const fn new(re: f32, im: f32) -> Self {
        C32 { re, im }
    }

    /// `e^{iθ}`.
    #[inline]
    pub fn cis(theta: f32) -> Self {
        let (s, c) = theta.sin_cos();
        C32 { re: c, im: s }
    }

    /// The n-th root of unity `e^{-2πi k/n}` (forward FFT sign). Computed
    /// in f64 so twiddle tables stay accurate for large n.
    #[inline]
    pub fn root_of_unity(k: i64, n: usize) -> Self {
        let ang = -2.0 * std::f64::consts::PI * (k as f64) / (n as f64);
        C32 { re: ang.cos() as f32, im: ang.sin() as f32 }
    }

    #[inline(always)]
    pub fn conj(self) -> Self {
        C32 { re: self.re, im: -self.im }
    }

    #[inline(always)]
    pub fn scale(self, s: f32) -> Self {
        C32 { re: self.re * s, im: self.im * s }
    }

    #[inline(always)]
    pub fn norm_sq(self) -> f32 {
        self.re * self.re + self.im * self.im
    }

    #[inline(always)]
    pub fn abs(self) -> f32 {
        self.norm_sq().sqrt()
    }

    /// Fused multiply-add `self + a*b` — the butterfly workhorse.
    #[inline(always)]
    pub fn mul_add(self, a: C32, b: C32) -> Self {
        C32 {
            re: a.re.mul_add(b.re, (-a.im).mul_add(b.im, self.re)),
            im: a.re.mul_add(b.im, a.im.mul_add(b.re, self.im)),
        }
    }

    /// Multiply by `i` (quarter turn) without a full complex multiply.
    #[inline(always)]
    pub fn mul_i(self) -> Self {
        C32 { re: -self.im, im: self.re }
    }
}

impl Add for C32 {
    type Output = C32;
    #[inline(always)]
    fn add(self, o: C32) -> C32 {
        C32 { re: self.re + o.re, im: self.im + o.im }
    }
}

impl Sub for C32 {
    type Output = C32;
    #[inline(always)]
    fn sub(self, o: C32) -> C32 {
        C32 { re: self.re - o.re, im: self.im - o.im }
    }
}

impl Mul for C32 {
    type Output = C32;
    #[inline(always)]
    fn mul(self, o: C32) -> C32 {
        C32 {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }
}

impl Neg for C32 {
    type Output = C32;
    #[inline(always)]
    fn neg(self) -> C32 {
        C32 { re: -self.re, im: -self.im }
    }
}

impl AddAssign for C32 {
    #[inline(always)]
    fn add_assign(&mut self, o: C32) {
        self.re += o.re;
        self.im += o.im;
    }
}

impl SubAssign for C32 {
    #[inline(always)]
    fn sub_assign(&mut self, o: C32) {
        self.re -= o.re;
        self.im -= o.im;
    }
}

impl MulAssign for C32 {
    #[inline(always)]
    fn mul_assign(&mut self, o: C32) {
        *self = *self * o;
    }
}

impl From<f32> for C32 {
    fn from(re: f32) -> Self {
        C32 { re, im: 0.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn field_axioms_spot() {
        let a = C32::new(1.5, -2.0);
        let b = C32::new(-0.5, 3.0);
        let c = C32::new(2.0, 0.25);
        assert_eq!(a + b, b + a);
        assert_eq!((a + b) + c, a + (b + c));
        let lhs = a * (b + c);
        let rhs = a * b + a * c;
        assert!((lhs - rhs).abs() < 1e-5);
    }

    #[test]
    fn conj_and_norm() {
        let a = C32::new(3.0, 4.0);
        assert_eq!(a.norm_sq(), 25.0);
        assert_eq!((a * a.conj()).re, 25.0);
        assert!((a * a.conj()).im.abs() < 1e-6);
    }

    #[test]
    fn roots_of_unity_cycle() {
        let w = C32::root_of_unity(1, 8);
        let mut acc = C32::ONE;
        for _ in 0..8 {
            acc = acc * w;
        }
        assert!((acc - C32::ONE).abs() < 1e-5);
    }

    #[test]
    fn mul_i_is_quarter_turn() {
        let a = C32::new(2.0, 5.0);
        assert_eq!(a.mul_i(), a * C32::new(0.0, 1.0));
    }

    #[test]
    fn mul_add_matches_expanded() {
        let a = C32::new(0.3, -1.2);
        let b = C32::new(2.0, 0.7);
        let acc = C32::new(-5.0, 4.0);
        let got = acc.mul_add(a, b);
        let want = acc + a * b;
        assert!((got - want).abs() < 1e-5);
    }
}
