//! 2-D real transforms by row–column decomposition on the vendor planner
//! — including the explicit transposition passes a black-box library
//! forces (paper Table 1 / Table 5's `TRANS.` columns). The fbfft host
//! engine elides these; this module deliberately does not.

use super::complex::C32;
use super::plan::{cached, Direction};
use super::real::{irfft, rfft, rfft_len};

/// Forward 2-D R2C of a row-major `h_in × w_in` image zero-padded onto an
/// `n × n` basis. Output row-major `n × (n/2+1)`: bin `[kh][kw]`.
pub fn rfft2(img: &[f32], h_in: usize, w_in: usize, n: usize) -> Vec<C32> {
    assert_eq!(img.len(), h_in * w_in);
    assert!(h_in <= n && w_in <= n, "image exceeds basis");
    let nf = rfft_len(n);
    // vendor-style: materialize the zero-padded row before transforming
    let mut rows = vec![C32::ZERO; n * nf];
    let mut padded = vec![0f32; n];
    for r in 0..h_in {
        padded[..w_in].copy_from_slice(&img[r * w_in..(r + 1) * w_in]);
        let f = rfft(&padded, n);
        rows[r * nf..(r + 1) * nf].copy_from_slice(&f);
    }
    // rows h_in..n are transforms of zero rows — already zero.
    // columns: full complex FFT per kw bin (explicit gather = the
    // transpose a black-box 1-D API imposes)
    let plan = cached(n);
    let mut out = vec![C32::ZERO; n * nf];
    let mut col = vec![C32::ZERO; n];
    for kw in 0..nf {
        for r in 0..n {
            col[r] = rows[r * nf + kw];
        }
        let f = plan.transform(&col, Direction::Forward);
        for kh in 0..n {
            out[kh * nf + kw] = f[kh];
        }
    }
    out
}

/// Inverse 2-D C2R of an `n × (n/2+1)` half-spectrum, clipped to
/// `clip_h × clip_w` (row-major output).
pub fn irfft2(spec: &[C32], n: usize, clip_h: usize, clip_w: usize) -> Vec<f32> {
    let nf = rfft_len(n);
    assert_eq!(spec.len(), n * nf);
    assert!(clip_h <= n && clip_w <= n);
    // columns first (inverse of the forward order), normalized by n here
    let plan = cached(n);
    let mut mid = vec![C32::ZERO; n * nf];
    let mut col = vec![C32::ZERO; n];
    for kw in 0..nf {
        for kh in 0..n {
            col[kh] = spec[kh * nf + kw];
        }
        let t = plan.inverse_normalized(&col);
        for r in 0..n {
            mid[r * nf + kw] = t[r];
        }
    }
    // rows: C2R per row, then clip
    let mut out = vec![0f32; clip_h * clip_w];
    for r in 0..clip_h {
        let row = irfft(&mid[r * nf..(r + 1) * nf], n);
        out[r * clip_w..(r + 1) * clip_w].copy_from_slice(&row[..clip_w]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_img(h: usize, w: usize, seed: u64) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0xA24BAED4963EE407) | 1;
        (0..h * w)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                (s as f64 / u64::MAX as f64) as f32 * 2.0 - 1.0
            })
            .collect()
    }

    /// naive 2-D DFT bins for cross-checking
    fn naive_bin(img: &[f32], h: usize, w: usize, n: usize, kh: usize,
                 kw: usize) -> C32 {
        let mut acc_re = 0f64;
        let mut acc_im = 0f64;
        for r in 0..h {
            for c in 0..w {
                let ang = -2.0 * std::f64::consts::PI
                    * ((kh * r) as f64 + (kw * c) as f64)
                    / n as f64;
                acc_re += img[r * w + c] as f64 * ang.cos();
                acc_im += img[r * w + c] as f64 * ang.sin();
            }
        }
        C32::new(acc_re as f32, acc_im as f32)
    }

    #[test]
    fn matches_naive_2d() {
        let (h, w, n) = (5, 6, 8);
        let img = rand_img(h, w, 3);
        let f = rfft2(&img, h, w, n);
        for kh in 0..n {
            for kw in 0..rfft_len(n) {
                let want = naive_bin(&img, h, w, n, kh, kw);
                let got = f[kh * rfft_len(n) + kw];
                assert!((got - want).abs() < 1e-3,
                        "({kh},{kw}): {got:?} vs {want:?}");
            }
        }
    }

    #[test]
    fn round_trip_with_clip() {
        let (h, w, n) = (7, 5, 8);
        let img = rand_img(h, w, 9);
        let f = rfft2(&img, h, w, n);
        let back = irfft2(&f, n, h, w);
        for (b, o) in back.iter().zip(&img) {
            assert!((b - o).abs() < 1e-4);
        }
    }

    #[test]
    fn works_on_non_pow2_basis() {
        // the autotuner explores smooth non-power-of-two bases
        let (h, w, n) = (5, 5, 12);
        let img = rand_img(h, w, 4);
        let f = rfft2(&img, h, w, n);
        let back = irfft2(&f, n, h, w);
        for (b, o) in back.iter().zip(&img) {
            assert!((b - o).abs() < 1e-4);
        }
    }
}
