//! 2-D real transforms by row–column decomposition on the vendor planner
//! — including the explicit transposition passes a black-box library
//! forces (paper Table 1 / Table 5's `TRANS.` columns). The fbfft host
//! engine elides these; this module deliberately does not.
//!
//! The `_into` variants take the output and a caller-owned scratch slice
//! (size from [`scratch_len`]) so the convolution pipeline can run one
//! transform per plane across threads without per-plane buffer churn;
//! allocations *inside* the planner (`plan.transform` returns owned
//! spectra, mirroring a vendor library's internal workspace) remain its
//! own business, exactly as cuFFT's do.

use super::complex::C32;
use super::plan::{cached, Direction};
use super::real::{irfft, rfft, rfft_len};

/// `C32` scratch elements the `_into` transforms need for basis `n`:
/// one `n × (n/2+1)` row-spectrum plane plus one length-`n` column.
pub fn scratch_len(n: usize) -> usize {
    n * rfft_len(n) + n
}

/// Forward 2-D R2C of a row-major `h_in × w_in` image zero-padded onto an
/// `n × n` basis. Output row-major `n × (n/2+1)`: bin `[kh][kw]`.
pub fn rfft2(img: &[f32], h_in: usize, w_in: usize, n: usize) -> Vec<C32> {
    let mut out = vec![C32::ZERO; n * rfft_len(n)];
    let mut scratch = vec![C32::ZERO; scratch_len(n)];
    rfft2_into(img, h_in, w_in, n, &mut out, &mut scratch);
    out
}

/// [`rfft2`] into a caller-owned output, using caller-owned scratch of at
/// least [`scratch_len`]`(n)` elements.
pub fn rfft2_into(img: &[f32], h_in: usize, w_in: usize, n: usize,
                  out: &mut [C32], scratch: &mut [C32]) {
    assert_eq!(img.len(), h_in * w_in);
    assert!(h_in <= n && w_in <= n, "image exceeds basis");
    let nf = rfft_len(n);
    assert_eq!(out.len(), n * nf);
    assert!(scratch.len() >= scratch_len(n), "scratch too small");
    let (rows, col) = scratch.split_at_mut(n * nf);
    let col = &mut col[..n];
    // row pass: R2C per image row (rfft zero-pads w_in..n implicitly);
    // rows h_in..n are transforms of zero rows — cleared explicitly.
    for r in 0..h_in {
        let f = rfft(&img[r * w_in..(r + 1) * w_in], n);
        rows[r * nf..(r + 1) * nf].copy_from_slice(&f);
    }
    rows[h_in * nf..].fill(C32::ZERO);
    // columns: full complex FFT per kw bin (explicit gather = the
    // transpose a black-box 1-D API imposes)
    let plan = cached(n);
    for kw in 0..nf {
        for r in 0..n {
            col[r] = rows[r * nf + kw];
        }
        let f = plan.transform(col, Direction::Forward);
        for kh in 0..n {
            out[kh * nf + kw] = f[kh];
        }
    }
}

/// Inverse 2-D C2R of an `n × (n/2+1)` half-spectrum, clipped to
/// `clip_h × clip_w` (row-major output).
pub fn irfft2(spec: &[C32], n: usize, clip_h: usize, clip_w: usize) -> Vec<f32> {
    let mut out = vec![0f32; clip_h * clip_w];
    let mut scratch = vec![C32::ZERO; scratch_len(n)];
    irfft2_into(spec, n, clip_h, clip_w, &mut out, &mut scratch);
    out
}

/// [`irfft2`] into a caller-owned output, using caller-owned scratch of
/// at least [`scratch_len`]`(n)` elements.
pub fn irfft2_into(spec: &[C32], n: usize, clip_h: usize, clip_w: usize,
                   out: &mut [f32], scratch: &mut [C32]) {
    let nf = rfft_len(n);
    assert_eq!(spec.len(), n * nf);
    assert!(clip_h <= n && clip_w <= n);
    assert_eq!(out.len(), clip_h * clip_w);
    assert!(scratch.len() >= scratch_len(n), "scratch too small");
    let (mid, col) = scratch.split_at_mut(n * nf);
    let col = &mut col[..n];
    // columns first (inverse of the forward order), normalized by n here;
    // only the rows surviving the clip are materialized
    let plan = cached(n);
    for kw in 0..nf {
        for kh in 0..n {
            col[kh] = spec[kh * nf + kw];
        }
        let t = plan.inverse_normalized(col);
        for r in 0..clip_h {
            mid[r * nf + kw] = t[r];
        }
    }
    // rows: C2R per row, then clip
    for r in 0..clip_h {
        let row = irfft(&mid[r * nf..(r + 1) * nf], n);
        out[r * clip_w..(r + 1) * clip_w].copy_from_slice(&row[..clip_w]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_img(h: usize, w: usize, seed: u64) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0xA24BAED4963EE407) | 1;
        (0..h * w)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                (s as f64 / u64::MAX as f64) as f32 * 2.0 - 1.0
            })
            .collect()
    }

    /// naive 2-D DFT bins for cross-checking
    fn naive_bin(img: &[f32], h: usize, w: usize, n: usize, kh: usize,
                 kw: usize) -> C32 {
        let mut acc_re = 0f64;
        let mut acc_im = 0f64;
        for r in 0..h {
            for c in 0..w {
                let ang = -2.0 * std::f64::consts::PI
                    * ((kh * r) as f64 + (kw * c) as f64)
                    / n as f64;
                acc_re += img[r * w + c] as f64 * ang.cos();
                acc_im += img[r * w + c] as f64 * ang.sin();
            }
        }
        C32::new(acc_re as f32, acc_im as f32)
    }

    #[test]
    fn matches_naive_2d() {
        let (h, w, n) = (5, 6, 8);
        let img = rand_img(h, w, 3);
        let f = rfft2(&img, h, w, n);
        for kh in 0..n {
            for kw in 0..rfft_len(n) {
                let want = naive_bin(&img, h, w, n, kh, kw);
                let got = f[kh * rfft_len(n) + kw];
                assert!((got - want).abs() < 1e-3,
                        "({kh},{kw}): {got:?} vs {want:?}");
            }
        }
    }

    #[test]
    fn round_trip_with_clip() {
        let (h, w, n) = (7, 5, 8);
        let img = rand_img(h, w, 9);
        let f = rfft2(&img, h, w, n);
        let back = irfft2(&f, n, h, w);
        for (b, o) in back.iter().zip(&img) {
            assert!((b - o).abs() < 1e-4);
        }
    }

    #[test]
    fn works_on_non_pow2_basis() {
        // the autotuner explores smooth non-power-of-two bases
        let (h, w, n) = (5, 5, 12);
        let img = rand_img(h, w, 4);
        let f = rfft2(&img, h, w, n);
        let back = irfft2(&f, n, h, w);
        for (b, o) in back.iter().zip(&img) {
            assert!((b - o).abs() < 1e-4);
        }
    }

    #[test]
    fn into_variants_reuse_dirty_scratch() {
        // the pipeline hands the same scratch to every plane — stale
        // contents from a previous transform must not leak through
        let (h, w, n) = (6, 6, 8);
        let a = rand_img(h, w, 5);
        let b = rand_img(h, w, 6);
        let nf = rfft_len(n);
        let mut scratch = vec![C32::new(7.0, -7.0); scratch_len(n)];
        let mut fa = vec![C32::ZERO; n * nf];
        let mut fb = vec![C32::ZERO; n * nf];
        rfft2_into(&a, h, w, n, &mut fa, &mut scratch);
        rfft2_into(&b, h, w, n, &mut fb, &mut scratch);
        let wa = rfft2(&a, h, w, n);
        let wb = rfft2(&b, h, w, n);
        for (g, want) in fa.iter().zip(&wa).chain(fb.iter().zip(&wb)) {
            assert!((*g - *want).abs() < 1e-5);
        }
        let mut back = vec![0f32; h * w];
        irfft2_into(&fb, n, h, w, &mut back, &mut scratch);
        for (g, o) in back.iter().zip(&b) {
            assert!((g - o).abs() < 1e-4);
        }
    }
}
