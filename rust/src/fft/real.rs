//! Real-to-complex and complex-to-real transforms on the vendor planner.
//!
//! Hermitian-symmetric storage (`n/2 + 1` bins), the layout Table 1's
//! `⌊(w+p)/2⌋+1` dimensions come from. Even sizes use the classic
//! pack-into-half-size-complex trick; odd sizes fall back to a full
//! complex transform (matching a vendor library's internal dispatch).

use super::complex::C32;
use super::plan::{cached, Direction};

/// Number of stored bins for a real transform of size `n`.
pub fn rfft_len(n: usize) -> usize {
    n / 2 + 1
}

/// Forward R2C transform of `x`, zero-padded (explicitly, vendor-style)
/// or truncated to `n`. Returns `n/2 + 1` bins.
pub fn rfft(x: &[f32], n: usize) -> Vec<C32> {
    assert!(n >= 1);
    if n % 2 != 0 {
        return rfft_via_complex(x, n);
    }
    let half = n / 2;
    let plan = cached(half);
    // pack even/odd samples into one complex signal of length n/2
    let mut z = vec![C32::ZERO; half];
    for j in 0..half {
        let re = x.get(2 * j).copied().unwrap_or(0.0);
        let im = x.get(2 * j + 1).copied().unwrap_or(0.0);
        z[j] = C32::new(re, im);
    }
    let zf = plan.transform(&z, Direction::Forward);
    // unpack: X[k] = E[k] + e^{-2πik/n}·O[k]
    let mut out = vec![C32::ZERO; rfft_len(n)];
    for k in 0..=half {
        let zk = if k == half { zf[0] } else { zf[k] };
        let zc = zf[(half - k) % half].conj();
        let e = (zk + zc).scale(0.5);
        let o = (zk - zc).scale(0.5).mul_i().scale(-1.0); // (zk - zc)/(2i)
        out[k] = e + C32::root_of_unity(k as i64, n) * o;
    }
    out
}

fn rfft_via_complex(x: &[f32], n: usize) -> Vec<C32> {
    let plan = cached(n);
    let mut z = vec![C32::ZERO; n];
    for (j, zj) in z.iter_mut().enumerate() {
        *zj = C32::new(x.get(j).copied().unwrap_or(0.0), 0.0);
    }
    let f = plan.transform(&z, Direction::Forward);
    f[..rfft_len(n)].to_vec()
}

/// Inverse C2R transform of a half-spectrum (`n/2 + 1` bins), normalized,
/// returning `n` real samples.
pub fn irfft(spec: &[C32], n: usize) -> Vec<f32> {
    assert_eq!(spec.len(), rfft_len(n), "half-spectrum length mismatch");
    if n % 2 != 0 {
        return irfft_via_complex(spec, n);
    }
    let half = n / 2;
    let plan = cached(half);
    // repack: Z[k] = E[k] + e^{+2πik/n}·O[k] with E/O from X, X_mirror
    let mut z = vec![C32::ZERO; half];
    for (k, zk) in z.iter_mut().enumerate() {
        let xk = spec[k];
        let xm = spec[half - k].conj();
        let e = (xk + xm).scale(0.5);
        let o = (xk - xm).scale(0.5) * C32::root_of_unity(-(k as i64), n);
        *zk = e + o.mul_i();
    }
    let zt = plan.transform(&z, Direction::Inverse);
    let mut out = vec![0f32; n];
    let s = 1.0 / half as f32;
    for j in 0..half {
        out[2 * j] = zt[j].re * s;
        out[2 * j + 1] = zt[j].im * s;
    }
    out
}

fn irfft_via_complex(spec: &[C32], n: usize) -> Vec<f32> {
    let plan = cached(n);
    let mut full = vec![C32::ZERO; n];
    full[..spec.len()].copy_from_slice(spec);
    for k in spec.len()..n {
        full[k] = spec[n - k].conj();
    }
    let t = plan.inverse_normalized(&full);
    t.iter().map(|c| c.re).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::naive_dft;

    fn rand_real(n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0xD1342543DE82EF95) | 1;
        (0..n)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                (s as f64 / u64::MAX as f64) as f32 * 2.0 - 1.0
            })
            .collect()
    }

    fn rfft_naive(x: &[f32], n: usize) -> Vec<C32> {
        let z: Vec<C32> = (0..n)
            .map(|j| C32::new(x.get(j).copied().unwrap_or(0.0), 0.0))
            .collect();
        naive_dft(&z, false)[..rfft_len(n)].to_vec()
    }

    #[test]
    fn rfft_matches_naive_even_and_odd() {
        for n in [2usize, 4, 8, 9, 12, 15, 16, 27, 32, 64] {
            let x = rand_real(n, n as u64);
            let got = rfft(&x, n);
            let want = rfft_naive(&x, n);
            for (k, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!((*g - *w).abs() < 1e-3,
                        "n={n} k={k}: {g:?} vs {w:?}");
            }
        }
    }

    #[test]
    fn rfft_implicit_truncation_and_padding() {
        let x = rand_real(10, 1);
        // padding: transform of x at n=16 equals transform of x||zeros
        let mut xp = x.clone();
        xp.resize(16, 0.0);
        let a = rfft(&x, 16);
        let b = rfft(&xp, 16);
        for (u, v) in a.iter().zip(&b) {
            assert!((*u - *v).abs() < 1e-6);
        }
    }

    #[test]
    fn round_trip_even_odd() {
        for n in [4usize, 9, 16, 27, 64] {
            let x = rand_real(n, 77 + n as u64);
            let back = irfft(&rfft(&x, n), n);
            for (b, o) in back.iter().zip(&x) {
                assert!((b - o).abs() < 1e-4, "n={n}");
            }
        }
    }

    #[test]
    fn dc_bin_is_sum() {
        let x = rand_real(32, 9);
        let f = rfft(&x, 32);
        let sum: f32 = x.iter().sum();
        assert!((f[0].re - sum).abs() < 1e-3);
        assert!(f[0].im.abs() < 1e-4);
    }

    #[test]
    fn nyquist_bin_is_real() {
        let x = rand_real(16, 11);
        let f = rfft(&x, 16);
        assert!(f[8].im.abs() < 1e-4);
    }
}
