//! `fbfft_host` — the batched small-transform specialist (paper §5).
//!
//! The host-side twin of the Pallas kernels, carrying the paper's four
//! design points onto CPU so the Figure-7/8 benches can measure them
//! directly against the vendor-analogue planner:
//!
//! 1. **sizes 8–256 only, powers of two** — a fixed-size stack buffer per
//!    transform ('registers'), per-size cached twiddle + bit-reversal
//!    tables, fully unrolled radix-2 stages;
//! 2. **implicit zero-copy padding** (§5.1) — callers pass `n_in ≤ n`;
//!    the load loop simply stops at `n_in`. No padded scratch tensor is
//!    ever allocated, where the vendor path must materialize one;
//! 3. **two real transforms packed into one complex FFT** (§5.2) —
//!    consecutive batch rows share one butterfly pass;
//! 4. **fused transposed output** (§5.1) — the 2-D transform stores the
//!    frequency-transposed `(kw, kh, batch)` layout the CGEMM stage wants,
//!    eliding the separate transposition pass entirely.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::complex::C32;
use super::real::rfft_len;

pub const MAX_N: usize = 256;

/// Per-size cached state: stage twiddles + bit reversal.
pub struct FbfftPlan {
    n: usize,
    log2n: u32,
    /// bit-reversal permutation of 0..n
    bitrev: Vec<u32>,
    /// stage-major twiddles: for stage s (len = 2^s half-block), entries
    /// `tw[s][j] = W_{2^{s+1}}^j`, flattened with offsets `2^s - 1`.
    twiddles: Vec<C32>,
    /// unpack roots `W_n^k`, k = 0..n/2
    unpack: Vec<C32>,
}

impl FbfftPlan {
    pub fn new(n: usize) -> Self {
        assert!(n.is_power_of_two() && (2..=MAX_N).contains(&n),
                "fbfft supports power-of-two sizes 2..=256, got {n}");
        let log2n = n.trailing_zeros();
        let mut bitrev = vec![0u32; n];
        for (i, b) in bitrev.iter_mut().enumerate() {
            *b = (i as u32).reverse_bits() >> (32 - log2n);
        }
        // twiddle LUT: total Σ 2^s for s in 0..log2n = n-1 entries
        let mut twiddles = Vec::with_capacity(n - 1);
        for s in 0..log2n {
            let m = 1usize << (s + 1); // block size of this stage
            for j in 0..(m / 2) {
                twiddles.push(C32::root_of_unity(j as i64, m));
            }
        }
        let unpack = (0..=n / 2)
            .map(|k| C32::root_of_unity(k as i64, n))
            .collect();
        FbfftPlan { n, log2n, bitrev, twiddles, unpack }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    /// In-place complex FFT of a `self.n`-length buffer ('registers').
    /// Iterative radix-2 DIT with the cached LUTs.
    #[inline]
    pub fn cfft_in_place(&self, buf: &mut [C32], inverse: bool) {
        debug_assert_eq!(buf.len(), self.n);
        // bit-reversal permutation
        for i in 0..self.n {
            let j = self.bitrev[i] as usize;
            if i < j {
                buf.swap(i, j);
            }
        }
        let mut tw_off = 0usize;
        for s in 0..self.log2n {
            let half = 1usize << s;
            let m = half << 1;
            let tw = &self.twiddles[tw_off..tw_off + half];
            let mut base = 0;
            while base < self.n {
                for j in 0..half {
                    let w = if inverse { tw[j].conj() } else { tw[j] };
                    let a = buf[base + j];
                    let b = buf[base + j + half] * w;
                    buf[base + j] = a + b;
                    buf[base + j + half] = a - b;
                }
                base += m;
            }
            tw_off += half;
        }
    }

    /// Batched 1-D R2C with implicit zero padding: `input` is
    /// `batch × n_in` row-major (`n_in ≤ n`), `out` is
    /// `batch × (n/2+1)`. Consecutive rows are packed pairwise into one
    /// complex transform (paper §5.2).
    pub fn rfft_batch(&self, input: &[f32], n_in: usize, batch: usize,
                      out: &mut [C32]) {
        assert!(n_in <= self.n, "n_in {n_in} exceeds plan size {}", self.n);
        assert_eq!(input.len(), batch * n_in);
        let nf = rfft_len(self.n);
        assert_eq!(out.len(), batch * nf);
        let mut buf = [C32::ZERO; MAX_N];
        let n = self.n;
        let mut b = 0;
        while b < batch {
            let paired = b + 1 < batch;
            let row_a = &input[b * n_in..(b + 1) * n_in];
            // implicit padding: only the first n_in entries are loaded
            if paired {
                let row_b = &input[(b + 1) * n_in..(b + 2) * n_in];
                for j in 0..n_in {
                    buf[j] = C32::new(row_a[j], row_b[j]);
                }
            } else {
                for j in 0..n_in {
                    buf[j] = C32::new(row_a[j], 0.0);
                }
            }
            buf[n_in..n].fill(C32::ZERO);
            self.cfft_in_place(&mut buf[..n], false);
            // Hermitian unpack of the packed pair:
            // A[k] = (Z[k]+conj(Z[n-k]))/2, B[k] = -i(Z[k]-conj(Z[n-k]))/2
            let oa = &mut out[b * nf..(b + 1) * nf];
            for k in 0..nf {
                let zk = buf[k];
                let zc = buf[(n - k) % n].conj();
                oa[k] = (zk + zc).scale(0.5);
            }
            if paired {
                // second write borrows out again — split at the boundary
                let (_, rest) = out.split_at_mut((b + 1) * nf);
                let ob = &mut rest[..nf];
                for k in 0..nf {
                    let zk = buf[k];
                    let zc = buf[(n - k) % n].conj();
                    ob[k] = ((zk - zc).scale(0.5)).mul_i().scale(-1.0);
                }
            }
            b += 2;
        }
    }

    /// Batched 1-D C2R (normalized), pairwise-packed like `rfft_batch`,
    /// clipped to the first `clip` samples per row.
    pub fn irfft_batch(&self, spec: &[C32], batch: usize, clip: usize,
                       out: &mut [f32]) {
        let nf = rfft_len(self.n);
        assert!(clip <= self.n);
        assert_eq!(spec.len(), batch * nf);
        assert_eq!(out.len(), batch * clip);
        let n = self.n;
        let scale = 1.0 / n as f32;
        let mut buf = [C32::ZERO; MAX_N];
        let mut b = 0;
        while b < batch {
            let paired = b + 1 < batch;
            let sa = &spec[b * nf..(b + 1) * nf];
            // rebuild Z = A + i·B on the full circle via Hermitian ext.
            if paired {
                let sb = &spec[(b + 1) * nf..(b + 2) * nf];
                for k in 0..nf {
                    buf[k] = sa[k] + sb[k].mul_i();
                }
                for k in nf..n {
                    buf[k] = sa[n - k].conj() + sb[n - k].conj().mul_i();
                }
            } else {
                for k in 0..nf {
                    buf[k] = sa[k];
                }
                for k in nf..n {
                    buf[k] = sa[n - k].conj();
                }
            }
            self.cfft_in_place(&mut buf[..n], true);
            let oa = &mut out[b * clip..(b + 1) * clip];
            for (j, o) in oa.iter_mut().enumerate() {
                *o = buf[j].re * scale;
            }
            if paired {
                let (_, rest) = out.split_at_mut((b + 1) * clip);
                for (j, o) in rest[..clip].iter_mut().enumerate() {
                    *o = buf[j].im * scale;
                }
            }
            b += 2;
        }
    }

    /// One image's row pass: R2C along rows with §5.2 pair packing and
    /// implicit padding, into a row-spectrum plane `rows[..n·nf]`
    /// (row-major `n × nf`; rows `h_in..n` are zero).
    fn rfft_rows_one(&self, img: &[f32], h_in: usize, w_in: usize,
                     rows: &mut [C32], buf: &mut [C32; MAX_N]) {
        let n = self.n;
        let nf = rfft_len(n);
        rows[..n * nf].fill(C32::ZERO);
        let mut r = 0;
        while r < h_in {
            let paired = r + 1 < h_in;
            let ra = &img[r * w_in..(r + 1) * w_in];
            if paired {
                let rb = &img[(r + 1) * w_in..(r + 2) * w_in];
                for j in 0..w_in {
                    buf[j] = C32::new(ra[j], rb[j]);
                }
            } else {
                for j in 0..w_in {
                    buf[j] = C32::new(ra[j], 0.0);
                }
            }
            buf[w_in..n].fill(C32::ZERO);
            self.cfft_in_place(&mut buf[..n], false);
            for k in 0..nf {
                let zk = buf[k];
                let zc = buf[(n - k) % n].conj();
                rows[r * nf + k] = (zk + zc).scale(0.5);
                if paired {
                    rows[(r + 1) * nf + k] =
                        ((zk - zc).scale(0.5)).mul_i().scale(-1.0);
                }
            }
            r += 2;
        }
    }

    /// Pass 1 of the fused 2-D transform for a contiguous image range:
    /// `input` is `count × h_in × w_in`, `rows_out` receives `count`
    /// row-spectrum planes of `n × nf` each. The convolution pipeline
    /// threads this over image chunks (each chunk's output is
    /// contiguous), then runs [`FbfftPlan::rfft2_cols_transposed`] over
    /// kw ranges — together they equal [`FbfftPlan::rfft2_batch_transposed`].
    pub fn rfft2_rows(&self, input: &[f32], h_in: usize, w_in: usize,
                      count: usize, rows_out: &mut [C32]) {
        let n = self.n;
        let nf = rfft_len(n);
        assert!(h_in <= n && w_in <= n, "image exceeds basis");
        assert_eq!(input.len(), count * h_in * w_in);
        assert_eq!(rows_out.len(), count * n * nf);
        let mut buf = [C32::ZERO; MAX_N];
        for b in 0..count {
            self.rfft_rows_one(
                &input[b * h_in * w_in..(b + 1) * h_in * w_in], h_in,
                w_in, &mut rows_out[b * n * nf..(b + 1) * n * nf],
                &mut buf);
        }
    }

    /// Pass 2: column C2C over `kw ∈ [kw0, kw0+kwn)` for the whole
    /// batch, consuming [`FbfftPlan::rfft2_rows`] planes (`batch × n × nf`)
    /// and writing the fused-transposed chunk `kwn × n × batch` — the
    /// `[kw][kh][b]` slice of the full output starting at bin row `kw0`.
    /// kw chunks are contiguous in the output, so threads split it.
    pub fn rfft2_cols_transposed(&self, rows_all: &[C32], batch: usize,
                                 kw0: usize, kwn: usize,
                                 out_chunk: &mut [C32]) {
        let n = self.n;
        let nf = rfft_len(n);
        assert_eq!(rows_all.len(), batch * n * nf);
        assert!(kw0 + kwn <= nf);
        assert_eq!(out_chunk.len(), kwn * n * batch);
        let mut col = [C32::ZERO; MAX_N];
        for kw in kw0..kw0 + kwn {
            for b in 0..batch {
                for r in 0..n {
                    col[r] = rows_all[(b * n + r) * nf + kw];
                }
                self.cfft_in_place(&mut col[..n], false);
                for kh in 0..n {
                    out_chunk[((kw - kw0) * n + kh) * batch + b] = col[kh];
                }
            }
        }
    }

    /// Batched 2-D R2C with implicit padding and **fused transposed
    /// output**: `input` is `batch × h_in × w_in` row-major, `out` is
    /// `(n/2+1) × n × batch` — bin `[kw][kh][b]`, the HWBD layout the
    /// frequency CGEMM consumes with zero extra transposition passes.
    /// Serial; the pipeline uses the two phase entry points above to
    /// spread the same computation over threads.
    pub fn rfft2_batch_transposed(&self, input: &[f32], h_in: usize,
                                  w_in: usize, batch: usize,
                                  out: &mut [C32]) {
        let n = self.n;
        assert!(h_in <= n && w_in <= n, "image exceeds basis");
        assert_eq!(input.len(), batch * h_in * w_in);
        let nf = rfft_len(n);
        assert_eq!(out.len(), nf * n * batch);
        // scratch: one image's row-transformed planes, (h=n)×(nf)
        let mut rows = vec![C32::ZERO; n * nf];
        let mut col = [C32::ZERO; MAX_N];
        let mut buf = [C32::ZERO; MAX_N];
        for b in 0..batch {
            let img = &input[b * h_in * w_in..(b + 1) * h_in * w_in];
            self.rfft_rows_one(img, h_in, w_in, &mut rows, &mut buf);
            // pass 2: full C2C along columns; store transposed [kw][kh][b]
            for kw in 0..nf {
                for (r, c) in col[..n].iter_mut().enumerate() {
                    *c = rows[r * nf + kw];
                }
                self.cfft_in_place(&mut col[..n], false);
                for kh in 0..n {
                    out[(kw * n + kh) * batch + b] = col[kh];
                }
            }
        }
    }

    /// Inverse of one image `b` out of the fused-transposed spectrum
    /// (`nf × n × batch`), normalized and clipped to `clip_h × clip_w`.
    /// `rows` is caller scratch of at least `n·nf` (dirty contents fine —
    /// every cell read is written first). The pipeline threads this over
    /// image chunks with per-thread scratch.
    #[allow(clippy::too_many_arguments)]
    pub fn irfft2_one_transposed(&self, spec: &[C32], batch: usize,
                                 b: usize, clip_h: usize, clip_w: usize,
                                 rows: &mut [C32], out: &mut [f32]) {
        let n = self.n;
        let nf = rfft_len(n);
        assert_eq!(spec.len(), nf * n * batch);
        assert!(b < batch);
        assert!(clip_h <= n && clip_w <= n);
        assert_eq!(out.len(), clip_h * clip_w);
        assert!(rows.len() >= n * nf, "rows scratch too small");
        let scale = 1.0 / (n * n) as f32;
        let mut col = [C32::ZERO; MAX_N];
        let mut buf = [C32::ZERO; MAX_N];
        // pass 1: inverse along kh for each kw bin (input is already
        // kw-major: a contiguous-ish walk, no pre-transpose needed)
        for kw in 0..nf {
            for kh in 0..n {
                col[kh] = spec[(kw * n + kh) * batch + b];
            }
            self.cfft_in_place(&mut col[..n], true);
            for r in 0..clip_h {
                rows[r * nf + kw] = col[r];
            }
        }
        // pass 2: C2R along rows for the clipped rows only
        for r in 0..clip_h {
            for k in 0..nf {
                buf[k] = rows[r * nf + k];
            }
            for k in nf..n {
                buf[k] = rows[r * nf + (n - k)].conj();
            }
            self.cfft_in_place(&mut buf[..n], true);
            for c in 0..clip_w {
                out[r * clip_w + c] = buf[c].re * scale;
            }
        }
    }

    /// Batched 2-D C2R from the transposed `(n/2+1) × n × batch` layout,
    /// normalized, clipped to `clip_h × clip_w` per image (the fused clip
    /// of the convolution pipeline). Output `batch × clip_h × clip_w`.
    pub fn irfft2_batch_transposed(&self, spec: &[C32], batch: usize,
                                   clip_h: usize, clip_w: usize,
                                   out: &mut [f32]) {
        let n = self.n;
        let nf = rfft_len(n);
        assert_eq!(spec.len(), nf * n * batch);
        assert!(clip_h <= n && clip_w <= n);
        assert_eq!(out.len(), batch * clip_h * clip_w);
        let mut rows = vec![C32::ZERO; n * nf];
        for b in 0..batch {
            self.irfft2_one_transposed(
                spec, batch, b, clip_h, clip_w, &mut rows,
                &mut out[b * clip_h * clip_w..(b + 1) * clip_h * clip_w]);
        }
    }

    /// Reference unpack root accessor (used by conv engines).
    pub fn unpack_root(&self, k: usize) -> C32 {
        self.unpack[k]
    }

    /// Shared twiddle LUT accessor (stage-major layout; used by the
    /// DIF/DIT no-bit-reversal variants in `fft::dif`).
    #[inline]
    pub fn twiddle(&self, idx: usize, inverse: bool) -> C32 {
        let w = self.twiddles[idx];
        if inverse { w.conj() } else { w }
    }
}

/// Process-wide fbfft plan cache.
pub fn cached(n: usize) -> Arc<FbfftPlan> {
    static CACHE: OnceLock<Mutex<HashMap<usize, Arc<FbfftPlan>>>> =
        OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let mut guard = cache.lock().expect("fbfft plan cache poisoned");
    guard.entry(n).or_insert_with(|| Arc::new(FbfftPlan::new(n))).clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::real::{irfft, rfft};

    fn rand_real(len: usize, seed: u64) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        (0..len)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                (s as f64 / u64::MAX as f64) as f32 * 2.0 - 1.0
            })
            .collect()
    }

    #[test]
    fn rejects_unsupported_sizes() {
        for n in [0usize, 1, 3, 12, 512] {
            assert!(std::panic::catch_unwind(|| FbfftPlan::new(n)).is_err(),
                    "n={n} should be rejected");
        }
    }

    #[test]
    fn rfft_batch_matches_planner_all_sizes() {
        for n in [2usize, 4, 8, 16, 32, 64, 128, 256] {
            let batch = 5; // odd: exercises the unpaired tail
            let x = rand_real(batch * n, n as u64);
            let plan = FbfftPlan::new(n);
            let mut out = vec![C32::ZERO; batch * (n / 2 + 1)];
            plan.rfft_batch(&x, n, batch, &mut out);
            for b in 0..batch {
                let want = rfft(&x[b * n..(b + 1) * n], n);
                for (k, w) in want.iter().enumerate() {
                    let g = out[b * (n / 2 + 1) + k];
                    assert!((g - *w).abs() < 2e-3 * (n as f32).sqrt(),
                            "n={n} b={b} k={k}: {g:?} vs {w:?}");
                }
            }
        }
    }

    #[test]
    fn implicit_padding_matches_explicit() {
        let (n, n_in, batch) = (32usize, 13usize, 4usize);
        let x = rand_real(batch * n_in, 7);
        let plan = FbfftPlan::new(n);
        let mut got = vec![C32::ZERO; batch * (n / 2 + 1)];
        plan.rfft_batch(&x, n_in, batch, &mut got);
        for b in 0..batch {
            let mut padded = x[b * n_in..(b + 1) * n_in].to_vec();
            padded.resize(n, 0.0);
            let want = rfft(&padded, n);
            for (k, w) in want.iter().enumerate() {
                assert!((got[b * (n / 2 + 1) + k] - *w).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn irfft_batch_round_trip_with_clip() {
        let (n, batch, clip) = (64usize, 7usize, 40usize);
        let x = rand_real(batch * n, 3);
        let plan = FbfftPlan::new(n);
        let nf = n / 2 + 1;
        let mut spec = vec![C32::ZERO; batch * nf];
        plan.rfft_batch(&x, n, batch, &mut spec);
        let mut back = vec![0f32; batch * clip];
        plan.irfft_batch(&spec, batch, clip, &mut back);
        for b in 0..batch {
            for j in 0..clip {
                assert!((back[b * clip + j] - x[b * n + j]).abs() < 1e-3,
                        "b={b} j={j}");
            }
        }
    }

    #[test]
    fn rfft2_transposed_matches_vendor_2d() {
        use crate::fft::fft2d::rfft2;
        let (n, h, w, batch) = (16usize, 11usize, 9usize, 3usize);
        let x = rand_real(batch * h * w, 5);
        let plan = FbfftPlan::new(n);
        let nf = n / 2 + 1;
        let mut out = vec![C32::ZERO; nf * n * batch];
        plan.rfft2_batch_transposed(&x, h, w, batch, &mut out);
        for b in 0..batch {
            let want = rfft2(&x[b * h * w..(b + 1) * h * w], h, w, n);
            for kh in 0..n {
                for kw in 0..nf {
                    let g = out[(kw * n + kh) * batch + b];
                    let wv = want[kh * nf + kw];
                    assert!((g - wv).abs() < 3e-3,
                            "b={b} ({kh},{kw}): {g:?} vs {wv:?}");
                }
            }
        }
    }

    #[test]
    fn irfft2_transposed_round_trip() {
        let (n, h, w, batch) = (16usize, 12usize, 10usize, 4usize);
        let x = rand_real(batch * h * w, 8);
        let plan = FbfftPlan::new(n);
        let nf = n / 2 + 1;
        let mut spec = vec![C32::ZERO; nf * n * batch];
        plan.rfft2_batch_transposed(&x, h, w, batch, &mut spec);
        let mut back = vec![0f32; batch * h * w];
        plan.irfft2_batch_transposed(&spec, batch, h, w, &mut back);
        for (g, o) in back.iter().zip(&x) {
            assert!((g - o).abs() < 2e-3);
        }
    }

    #[test]
    fn phase_split_equals_fused_batch() {
        // the threaded pipeline runs rows-then-columns in two phases and
        // kw chunks; it must reproduce the fused serial batch bitwise
        let (n, h, w, batch) = (16usize, 11usize, 9usize, 5usize);
        let x = rand_real(batch * h * w, 12);
        let plan = FbfftPlan::new(n);
        let nf = n / 2 + 1;
        let mut want = vec![C32::ZERO; nf * n * batch];
        plan.rfft2_batch_transposed(&x, h, w, batch, &mut want);
        let mut rows_all = vec![C32::ZERO; batch * n * nf];
        plan.rfft2_rows(&x, h, w, batch, &mut rows_all);
        let mut got = vec![C32::ZERO; nf * n * batch];
        let split = nf / 2;
        {
            let (lo, hi) = got.split_at_mut(split * n * batch);
            plan.rfft2_cols_transposed(&rows_all, batch, 0, split, lo);
            plan.rfft2_cols_transposed(&rows_all, batch, split,
                                       nf - split, hi);
        }
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(*g, *w);
        }
    }

    #[test]
    fn one_image_inverse_with_dirty_scratch() {
        let (n, h, w, batch) = (16usize, 12usize, 10usize, 3usize);
        let x = rand_real(batch * h * w, 13);
        let plan = FbfftPlan::new(n);
        let nf = n / 2 + 1;
        let mut spec = vec![C32::ZERO; nf * n * batch];
        plan.rfft2_batch_transposed(&x, h, w, batch, &mut spec);
        let mut rows = vec![C32::new(3.0, -9.0); n * nf]; // stale junk
        for b in 0..batch {
            let mut img = vec![0f32; h * w];
            plan.irfft2_one_transposed(&spec, batch, b, h, w, &mut rows,
                                       &mut img);
            for (g, o) in img.iter().zip(&x[b * h * w..(b + 1) * h * w]) {
                assert!((g - o).abs() < 2e-3);
            }
        }
    }

    #[test]
    fn single_row_batch_works() {
        // batch = 1 exercises the unpaired path end to end
        let n = 32;
        let x = rand_real(n, 9);
        let plan = FbfftPlan::new(n);
        let mut spec = vec![C32::ZERO; n / 2 + 1];
        plan.rfft_batch(&x, n, 1, &mut spec);
        let want = rfft(&x, n);
        for (g, w) in spec.iter().zip(&want) {
            assert!((*g - *w).abs() < 1e-3);
        }
        let mut back = vec![0f32; n];
        plan.irfft_batch(&spec, 1, n, &mut back);
        for (g, o) in back.iter().zip(&x) {
            assert!((g - o).abs() < 1e-3);
        }
    }
}
