//! `fbfft_host` — the batched small-transform specialist (paper §5).
//!
//! The host-side twin of the Pallas kernels, carrying the paper's four
//! design points onto CPU so the Figure-7/8 benches can measure them
//! directly against the vendor-analogue planner:
//!
//! 1. **sizes 8–256 only, powers of two** — a fixed-size stack buffer per
//!    transform ('registers'), per-size cached twiddle + bit-reversal
//!    tables, fully unrolled radix-2 stages;
//! 2. **implicit zero-copy padding** (§5.1) — callers pass `n_in ≤ n`;
//!    the load loop simply stops at `n_in`. No padded scratch tensor is
//!    ever allocated, where the vendor path must materialize one;
//! 3. **two real transforms packed into one complex FFT** (§5.2) —
//!    consecutive batch rows share one butterfly pass;
//! 4. **fused transposed output** (§5.1) — the 2-D transform stores the
//!    frequency-transposed `(kw, kh, batch)` layout the CGEMM stage wants,
//!    eliding the separate transposition pass entirely.

use std::sync::{Arc, OnceLock};

use super::complex::C32;
use super::real::rfft_len;
use super::soa;

pub const MAX_N: usize = 256;

/// Per-size cached state: stage twiddles + bit reversal.
pub struct FbfftPlan {
    n: usize,
    log2n: u32,
    /// bit-reversal permutation of 0..n
    bitrev: Vec<u32>,
    /// stage-major twiddles: for stage s (len = 2^s half-block), entries
    /// `tw[s][j] = W_{2^{s+1}}^j`, flattened with offsets `2^s - 1`.
    twiddles: Vec<C32>,
    /// unpack roots `W_n^k`, k = 0..n/2
    unpack: Vec<C32>,
}

impl FbfftPlan {
    pub fn new(n: usize) -> Self {
        assert!(n.is_power_of_two() && (2..=MAX_N).contains(&n),
                "fbfft supports power-of-two sizes 2..=256, got {n}");
        let log2n = n.trailing_zeros();
        let mut bitrev = vec![0u32; n];
        for (i, b) in bitrev.iter_mut().enumerate() {
            *b = (i as u32).reverse_bits() >> (32 - log2n);
        }
        // twiddle LUT: total Σ 2^s for s in 0..log2n = n-1 entries
        let mut twiddles = Vec::with_capacity(n - 1);
        for s in 0..log2n {
            let m = 1usize << (s + 1); // block size of this stage
            for j in 0..(m / 2) {
                twiddles.push(C32::root_of_unity(j as i64, m));
            }
        }
        let unpack = (0..=n / 2)
            .map(|k| C32::root_of_unity(k as i64, n))
            .collect();
        FbfftPlan { n, log2n, bitrev, twiddles, unpack }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    /// In-place complex FFT of a `self.n`-length buffer ('registers').
    /// Iterative radix-2 DIT with the cached LUTs.
    #[inline]
    pub fn cfft_in_place(&self, buf: &mut [C32], inverse: bool) {
        debug_assert_eq!(buf.len(), self.n);
        // bit-reversal permutation
        for i in 0..self.n {
            let j = self.bitrev[i] as usize;
            if i < j {
                buf.swap(i, j);
            }
        }
        let mut tw_off = 0usize;
        for s in 0..self.log2n {
            let half = 1usize << s;
            let m = half << 1;
            let tw = &self.twiddles[tw_off..tw_off + half];
            let mut base = 0;
            while base < self.n {
                for j in 0..half {
                    let w = if inverse { tw[j].conj() } else { tw[j] };
                    let a = buf[base + j];
                    let b = buf[base + j + half] * w;
                    buf[base + j] = a + b;
                    buf[base + j + half] = a - b;
                }
                base += m;
            }
            tw_off += half;
        }
    }

    /// Batched 1-D R2C with implicit zero padding: `input` is
    /// `batch × n_in` row-major (`n_in ≤ n`), `out` is
    /// `batch × (n/2+1)`. Consecutive rows are packed pairwise into one
    /// complex transform (paper §5.2).
    pub fn rfft_batch(&self, input: &[f32], n_in: usize, batch: usize,
                      out: &mut [C32]) {
        assert!(n_in <= self.n, "n_in {n_in} exceeds plan size {}", self.n);
        assert_eq!(input.len(), batch * n_in);
        let nf = rfft_len(self.n);
        assert_eq!(out.len(), batch * nf);
        let mut buf = [C32::ZERO; MAX_N];
        let n = self.n;
        let mut b = 0;
        while b < batch {
            let paired = b + 1 < batch;
            let row_a = &input[b * n_in..(b + 1) * n_in];
            // implicit padding: only the first n_in entries are loaded
            if paired {
                let row_b = &input[(b + 1) * n_in..(b + 2) * n_in];
                for j in 0..n_in {
                    buf[j] = C32::new(row_a[j], row_b[j]);
                }
            } else {
                for j in 0..n_in {
                    buf[j] = C32::new(row_a[j], 0.0);
                }
            }
            // only the padding tail needs clearing — positions 0..n_in
            // were just overwritten (no redundant full-buffer memset on
            // the n_in == n fast path)
            if n_in < n {
                buf[n_in..n].fill(C32::ZERO);
            }
            self.cfft_in_place(&mut buf[..n], false);
            // Hermitian unpack of the packed pair:
            // A[k] = (Z[k]+conj(Z[n-k]))/2, B[k] = -i(Z[k]-conj(Z[n-k]))/2
            let oa = &mut out[b * nf..(b + 1) * nf];
            for k in 0..nf {
                let zk = buf[k];
                let zc = buf[(n - k) % n].conj();
                oa[k] = (zk + zc).scale(0.5);
            }
            if paired {
                // second write borrows out again — split at the boundary
                let (_, rest) = out.split_at_mut((b + 1) * nf);
                let ob = &mut rest[..nf];
                for k in 0..nf {
                    let zk = buf[k];
                    let zc = buf[(n - k) % n].conj();
                    ob[k] = ((zk - zc).scale(0.5)).mul_i().scale(-1.0);
                }
            }
            b += 2;
        }
    }

    /// Batched 1-D C2R (normalized), pairwise-packed like `rfft_batch`,
    /// clipped to the first `clip` samples per row.
    pub fn irfft_batch(&self, spec: &[C32], batch: usize, clip: usize,
                       out: &mut [f32]) {
        let nf = rfft_len(self.n);
        assert!(clip <= self.n);
        assert_eq!(spec.len(), batch * nf);
        assert_eq!(out.len(), batch * clip);
        let n = self.n;
        let scale = 1.0 / n as f32;
        let mut buf = [C32::ZERO; MAX_N];
        let mut b = 0;
        while b < batch {
            let paired = b + 1 < batch;
            let sa = &spec[b * nf..(b + 1) * nf];
            // rebuild Z = A + i·B on the full circle via Hermitian ext.
            if paired {
                let sb = &spec[(b + 1) * nf..(b + 2) * nf];
                for k in 0..nf {
                    buf[k] = sa[k] + sb[k].mul_i();
                }
                for k in nf..n {
                    buf[k] = sa[n - k].conj() + sb[n - k].conj().mul_i();
                }
            } else {
                for k in 0..nf {
                    buf[k] = sa[k];
                }
                for k in nf..n {
                    buf[k] = sa[n - k].conj();
                }
            }
            self.cfft_in_place(&mut buf[..n], true);
            let oa = &mut out[b * clip..(b + 1) * clip];
            for (j, o) in oa.iter_mut().enumerate() {
                *o = buf[j].re * scale;
            }
            if paired {
                let (_, rest) = out.split_at_mut((b + 1) * clip);
                for (j, o) in rest[..clip].iter_mut().enumerate() {
                    *o = buf[j].im * scale;
                }
            }
            b += 2;
        }
    }

    /// One image's row pass: R2C along rows with §5.2 pair packing and
    /// implicit padding, into a row-spectrum plane `rows[..n·nf]`
    /// (row-major `n × nf`; rows `h_in..n` are zero).
    fn rfft_rows_one(&self, img: &[f32], h_in: usize, w_in: usize,
                     rows: &mut [C32], buf: &mut [C32; MAX_N]) {
        let n = self.n;
        let nf = rfft_len(n);
        // rows 0..h_in are fully written by the unpack loop below; only
        // the zero-row tail h_in..n actually needs clearing
        rows[h_in * nf..n * nf].fill(C32::ZERO);
        let mut r = 0;
        while r < h_in {
            let paired = r + 1 < h_in;
            let ra = &img[r * w_in..(r + 1) * w_in];
            if paired {
                let rb = &img[(r + 1) * w_in..(r + 2) * w_in];
                for j in 0..w_in {
                    buf[j] = C32::new(ra[j], rb[j]);
                }
            } else {
                for j in 0..w_in {
                    buf[j] = C32::new(ra[j], 0.0);
                }
            }
            if w_in < n {
                buf[w_in..n].fill(C32::ZERO);
            }
            self.cfft_in_place(&mut buf[..n], false);
            for k in 0..nf {
                let zk = buf[k];
                let zc = buf[(n - k) % n].conj();
                rows[r * nf + k] = (zk + zc).scale(0.5);
                if paired {
                    rows[(r + 1) * nf + k] =
                        ((zk - zc).scale(0.5)).mul_i().scale(-1.0);
                }
            }
            r += 2;
        }
    }

    /// Pass 1 of the fused 2-D transform for a contiguous image range:
    /// `input` is `count × h_in × w_in`, `rows_out` receives `count`
    /// row-spectrum planes of `n × nf` each. The convolution pipeline
    /// threads this over image chunks (each chunk's output is
    /// contiguous), then runs [`FbfftPlan::rfft2_cols_transposed`] over
    /// kw ranges — together they equal [`FbfftPlan::rfft2_batch_transposed`].
    pub fn rfft2_rows(&self, input: &[f32], h_in: usize, w_in: usize,
                      count: usize, rows_out: &mut [C32]) {
        let n = self.n;
        let nf = rfft_len(n);
        assert!(h_in <= n && w_in <= n, "image exceeds basis");
        assert_eq!(input.len(), count * h_in * w_in);
        assert_eq!(rows_out.len(), count * n * nf);
        let mut buf = [C32::ZERO; MAX_N];
        for b in 0..count {
            self.rfft_rows_one(
                &input[b * h_in * w_in..(b + 1) * h_in * w_in], h_in,
                w_in, &mut rows_out[b * n * nf..(b + 1) * n * nf],
                &mut buf);
        }
    }

    /// Pass 2: column C2C over `kw ∈ [kw0, kw0+kwn)` for the whole
    /// batch, consuming [`FbfftPlan::rfft2_rows`] planes (`batch × n × nf`)
    /// and writing the fused-transposed chunk `kwn × n × batch` — the
    /// `[kw][kh][b]` slice of the full output starting at bin row `kw0`.
    /// kw chunks are contiguous in the output, so threads split it.
    pub fn rfft2_cols_transposed(&self, rows_all: &[C32], batch: usize,
                                 kw0: usize, kwn: usize,
                                 out_chunk: &mut [C32]) {
        let n = self.n;
        let nf = rfft_len(n);
        assert_eq!(rows_all.len(), batch * n * nf);
        assert!(kw0 + kwn <= nf);
        assert_eq!(out_chunk.len(), kwn * n * batch);
        let mut col = [C32::ZERO; MAX_N];
        for kw in kw0..kw0 + kwn {
            for b in 0..batch {
                for r in 0..n {
                    col[r] = rows_all[(b * n + r) * nf + kw];
                }
                self.cfft_in_place(&mut col[..n], false);
                for kh in 0..n {
                    out_chunk[((kw - kw0) * n + kh) * batch + b] = col[kh];
                }
            }
        }
    }

    /// Batched 2-D R2C with implicit padding and **fused transposed
    /// output**: `input` is `batch × h_in × w_in` row-major, `out` is
    /// `(n/2+1) × n × batch` — bin `[kw][kh][b]`, the HWBD layout the
    /// frequency CGEMM consumes with zero extra transposition passes.
    /// Serial; the pipeline uses the two phase entry points above to
    /// spread the same computation over threads.
    pub fn rfft2_batch_transposed(&self, input: &[f32], h_in: usize,
                                  w_in: usize, batch: usize,
                                  out: &mut [C32]) {
        let n = self.n;
        assert!(h_in <= n && w_in <= n, "image exceeds basis");
        assert_eq!(input.len(), batch * h_in * w_in);
        let nf = rfft_len(n);
        assert_eq!(out.len(), nf * n * batch);
        // scratch: one image's row-transformed planes, (h=n)×(nf)
        let mut rows = vec![C32::ZERO; n * nf];
        let mut col = [C32::ZERO; MAX_N];
        let mut buf = [C32::ZERO; MAX_N];
        for b in 0..batch {
            let img = &input[b * h_in * w_in..(b + 1) * h_in * w_in];
            self.rfft_rows_one(img, h_in, w_in, &mut rows, &mut buf);
            // pass 2: full C2C along columns; store transposed [kw][kh][b]
            for kw in 0..nf {
                for (r, c) in col[..n].iter_mut().enumerate() {
                    *c = rows[r * nf + kw];
                }
                self.cfft_in_place(&mut col[..n], false);
                for kh in 0..n {
                    out[(kw * n + kh) * batch + b] = col[kh];
                }
            }
        }
    }

    /// Inverse of one image `b` out of the fused-transposed spectrum
    /// (`nf × n × batch`), normalized and clipped to `clip_h × clip_w`.
    /// `rows` is caller scratch of at least `n·nf` (dirty contents fine —
    /// every cell read is written first). The pipeline threads this over
    /// image chunks with per-thread scratch.
    #[allow(clippy::too_many_arguments)]
    pub fn irfft2_one_transposed(&self, spec: &[C32], batch: usize,
                                 b: usize, clip_h: usize, clip_w: usize,
                                 rows: &mut [C32], out: &mut [f32]) {
        let n = self.n;
        let nf = rfft_len(n);
        assert_eq!(spec.len(), nf * n * batch);
        assert!(b < batch);
        assert!(clip_h <= n && clip_w <= n);
        assert_eq!(out.len(), clip_h * clip_w);
        assert!(rows.len() >= n * nf, "rows scratch too small");
        let scale = 1.0 / (n * n) as f32;
        let mut col = [C32::ZERO; MAX_N];
        let mut buf = [C32::ZERO; MAX_N];
        // pass 1: inverse along kh for each kw bin (input is already
        // kw-major: a contiguous-ish walk, no pre-transpose needed)
        for kw in 0..nf {
            for kh in 0..n {
                col[kh] = spec[(kw * n + kh) * batch + b];
            }
            self.cfft_in_place(&mut col[..n], true);
            for r in 0..clip_h {
                rows[r * nf + kw] = col[r];
            }
        }
        // pass 2: C2R along rows for the clipped rows only
        for r in 0..clip_h {
            for k in 0..nf {
                buf[k] = rows[r * nf + k];
            }
            for k in nf..n {
                buf[k] = rows[r * nf + (n - k)].conj();
            }
            self.cfft_in_place(&mut buf[..n], true);
            for c in 0..clip_w {
                out[r * clip_w + c] = buf[c].re * scale;
            }
        }
    }

    /// Batched 2-D C2R from the transposed `(n/2+1) × n × batch` layout,
    /// normalized, clipped to `clip_h × clip_w` per image (the fused clip
    /// of the convolution pipeline). Output `batch × clip_h × clip_w`.
    pub fn irfft2_batch_transposed(&self, spec: &[C32], batch: usize,
                                   clip_h: usize, clip_w: usize,
                                   out: &mut [f32]) {
        let n = self.n;
        let nf = rfft_len(n);
        assert_eq!(spec.len(), nf * n * batch);
        assert!(clip_h <= n && clip_w <= n);
        assert_eq!(out.len(), batch * clip_h * clip_w);
        let mut rows = vec![C32::ZERO; n * nf];
        for b in 0..batch {
            self.irfft2_one_transposed(
                spec, batch, b, clip_h, clip_w, &mut rows,
                &mut out[b * clip_h * clip_w..(b + 1) * clip_h * clip_w]);
        }
    }

    /// Reference unpack root accessor (used by conv engines).
    pub fn unpack_root(&self, k: usize) -> C32 {
        self.unpack[k]
    }

    /// Shared twiddle LUT accessor (stage-major layout; used by the
    /// DIF/DIT no-bit-reversal variants in `fft::dif`).
    #[inline]
    pub fn twiddle(&self, idx: usize, inverse: bool) -> C32 {
        let w = self.twiddles[idx];
        if inverse { w.conj() } else { w }
    }

    /// Cached bit-reversal of index `i` (used by the SoA batch kernels,
    /// which permute whole lane rows instead of single elements).
    #[inline]
    pub fn bitrev(&self, i: usize) -> usize {
        self.bitrev[i] as usize
    }

    // ---- split-complex (SoA) batch-lane 2-D transforms ----------------
    //
    // The batched twins of the scalar 2-D path above, built on
    // [`crate::fft::soa::cfft_batch`]: every plane/row/column index is a
    // *lane*, batch is the contiguous innermost axis, and the complex
    // data lives in separate re/im `f32` planes. Layouts:
    //
    //   rows planes:  `[r][k][b]`   (n × nf × batch, batch innermost)
    //   output planes: `[kw][kh][b]` (nf × n × batch) — the same fused
    //   transposed bin-major layout as the scalar path, split-complex,
    //   handed to the planar CGEMM with **no repacking stage at all**.
    //
    // The lane kernels underneath dispatch on [`crate::util::simd`]'s
    // runtime tier (scalar reference / AVX2+FMA / AVX-512); a lane's
    // bits are independent of its batch position *within a tier*, so the
    // chunked-vs-fused bitwise assertions in this module's tests hold at
    // whatever tier the host detects (or `FBFFT_SIMD` forces).

    /// SoA pass 1 over the row-pair range `[rp0, rp0+rpn)` (row pairs of
    /// the §5.2 two-reals-in-one-complex pack; pair `rp` covers image
    /// rows `2rp` and `2rp+1`). All `batch` images advance in lanes.
    /// `rows_*` receive the `2·rpn × nf × batch` chunk starting at row
    /// `2·rp0`; `work_*` are per-caller scratch of `n·batch` (dirty ok).
    /// Threads split the full `0..n/2` pair range into contiguous chunks.
    #[allow(clippy::too_many_arguments)]
    pub fn rfft2_rows_soa(&self, input: &[f32], h_in: usize, w_in: usize,
                          batch: usize, rp0: usize, rpn: usize,
                          rows_re: &mut [f32], rows_im: &mut [f32],
                          work_re: &mut [f32], work_im: &mut [f32]) {
        let n = self.n;
        let nf = rfft_len(n);
        assert!(h_in <= n && w_in <= n, "image exceeds basis");
        assert_eq!(input.len(), batch * h_in * w_in);
        assert!(2 * (rp0 + rpn) <= n);
        assert_eq!(rows_re.len(), 2 * rpn * nf * batch);
        assert_eq!(rows_im.len(), 2 * rpn * nf * batch);
        assert!(work_re.len() >= n * batch && work_im.len() >= n * batch,
                "work scratch too small");
        if batch == 0 {
            return;
        }
        let work_re = &mut work_re[..n * batch];
        let work_im = &mut work_im[..n * batch];
        let hw = h_in * w_in;
        for rp in 0..rpn {
            let r0 = 2 * (rp0 + rp);
            let r1 = r0 + 1;
            let c0 = 2 * rp * nf * batch; // chunk offset of row r0
            let c1 = c0 + nf * batch; // chunk offset of row r1
            if r0 >= h_in {
                // transform of all-zero rows is zero — pure memset
                rows_re[c0..c1 + nf * batch].fill(0.0);
                rows_im[c0..c1 + nf * batch].fill(0.0);
                continue;
            }
            let paired = r1 < h_in;
            // lane load: row r0 → re plane, row r1 → im plane (§5.2);
            // b-outer keeps the image reads perfectly sequential
            for b in 0..batch {
                let ra = &input[b * hw + r0 * w_in..][..w_in];
                for (j, v) in ra.iter().enumerate() {
                    work_re[j * batch + b] = *v;
                }
                if paired {
                    let rb = &input[b * hw + r1 * w_in..][..w_in];
                    for (j, v) in rb.iter().enumerate() {
                        work_im[j * batch + b] = *v;
                    }
                } else {
                    for j in 0..w_in {
                        work_im[j * batch + b] = 0.0;
                    }
                }
            }
            // implicit padding: clear only the w_in..n tail
            if w_in < n {
                work_re[w_in * batch..].fill(0.0);
                work_im[w_in * batch..].fill(0.0);
            }
            soa::cfft_batch(self, work_re, work_im, batch, false);
            // Hermitian unpack of the packed pair, lane-wise per bin —
            // row r0 (A) lands below c1, row r1 (B) at or above it
            let (a_rows_re, b_rows_re) = rows_re.split_at_mut(c1);
            let (a_rows_im, b_rows_im) = rows_im.split_at_mut(c1);
            for k in 0..nf {
                let m = (n - k) % n;
                let a0 = c0 + k * batch;
                let b0 = k * batch; // offset within the post-c1 half
                let b_out = if paired {
                    Some((&mut b_rows_re[b0..b0 + batch],
                          &mut b_rows_im[b0..b0 + batch]))
                } else {
                    None
                };
                soa::unpack_pair_bin(
                    &work_re[k * batch..(k + 1) * batch],
                    &work_im[k * batch..(k + 1) * batch],
                    &work_re[m * batch..(m + 1) * batch],
                    &work_im[m * batch..(m + 1) * batch],
                    &mut a_rows_re[a0..a0 + batch],
                    &mut a_rows_im[a0..a0 + batch], b_out, batch);
            }
            if !paired {
                b_rows_re[..nf * batch].fill(0.0);
                b_rows_im[..nf * batch].fill(0.0);
            }
        }
    }

    /// SoA pass 2 over `kw ∈ [kw0, kw0+kwn)`: batched C2C down the
    /// columns of the full rows planes (`n × nf × batch`), writing the
    /// planar fused-transposed chunk `kwn × n × batch` in place — the
    /// gather lands directly in the output slab and the FFT runs there,
    /// so the column pass stores contiguously with zero extra copies.
    #[allow(clippy::too_many_arguments)]
    pub fn rfft2_cols_soa(&self, rows_re: &[f32], rows_im: &[f32],
                          batch: usize, kw0: usize, kwn: usize,
                          out_re: &mut [f32], out_im: &mut [f32]) {
        let n = self.n;
        let nf = rfft_len(n);
        assert_eq!(rows_re.len(), n * nf * batch);
        assert_eq!(rows_im.len(), n * nf * batch);
        assert!(kw0 + kwn <= nf);
        assert_eq!(out_re.len(), kwn * n * batch);
        assert_eq!(out_im.len(), kwn * n * batch);
        if batch == 0 {
            return;
        }
        for kw in kw0..kw0 + kwn {
            let oc = (kw - kw0) * n * batch;
            let oc_re = &mut out_re[oc..oc + n * batch];
            let oc_im = &mut out_im[oc..oc + n * batch];
            for r in 0..n {
                let src = (r * nf + kw) * batch;
                oc_re[r * batch..(r + 1) * batch]
                    .copy_from_slice(&rows_re[src..src + batch]);
                oc_im[r * batch..(r + 1) * batch]
                    .copy_from_slice(&rows_im[src..src + batch]);
            }
            soa::cfft_batch(self, oc_re, oc_im, batch, false);
        }
    }

    /// Batched 2-D R2C in split-complex form: `input` is
    /// `batch × h_in × w_in` row-major, the output planes hold the fused
    /// transposed `(n/2+1) × n × batch` bin-major layout. Serial
    /// convenience over the two phase entry points above (the pipeline
    /// threads those directly with pooled scratch).
    pub fn rfft2_batch_soa(&self, input: &[f32], h_in: usize, w_in: usize,
                           batch: usize, out_re: &mut [f32],
                           out_im: &mut [f32]) {
        let n = self.n;
        let nf = rfft_len(n);
        assert_eq!(out_re.len(), nf * n * batch);
        assert_eq!(out_im.len(), nf * n * batch);
        let mut rows_re = vec![0f32; n * nf * batch];
        let mut rows_im = vec![0f32; n * nf * batch];
        let mut work_re = vec![0f32; n * batch];
        let mut work_im = vec![0f32; n * batch];
        self.rfft2_rows_soa(input, h_in, w_in, batch, 0, n / 2,
                            &mut rows_re, &mut rows_im, &mut work_re,
                            &mut work_im);
        self.rfft2_cols_soa(&rows_re, &rows_im, batch, 0, nf, out_re,
                            out_im);
    }

    /// SoA inverse for the lane group `[b0, b0+bn)` out of the planar
    /// fused-transposed spectrum (`nf × n × batch`), normalized and
    /// clipped to `clip_h × clip_w` per image. `out_chunk` receives the
    /// `bn` images (`bn × clip_h × clip_w` row-major). `rows_*` scratch
    /// needs `clip_h·nf·bn`, `work_*` needs `n·bn` (dirty contents fine).
    /// The pipeline threads this over LANES-aligned batch groups.
    #[allow(clippy::too_many_arguments)]
    pub fn irfft2_soa_chunk(&self, spec_re: &[f32], spec_im: &[f32],
                            batch: usize, b0: usize, bn: usize,
                            clip_h: usize, clip_w: usize,
                            rows_re: &mut [f32], rows_im: &mut [f32],
                            work_re: &mut [f32], work_im: &mut [f32],
                            out_chunk: &mut [f32]) {
        let n = self.n;
        let nf = rfft_len(n);
        assert_eq!(spec_re.len(), nf * n * batch);
        assert_eq!(spec_im.len(), nf * n * batch);
        assert!(b0 + bn <= batch);
        assert!(clip_h <= n && clip_w <= n);
        assert_eq!(out_chunk.len(), bn * clip_h * clip_w);
        assert!(rows_re.len() >= clip_h * nf * bn
                && rows_im.len() >= clip_h * nf * bn,
                "rows scratch too small");
        assert!(work_re.len() >= n * bn && work_im.len() >= n * bn,
                "work scratch too small");
        if bn == 0 {
            return;
        }
        let work_re = &mut work_re[..n * bn];
        let work_im = &mut work_im[..n * bn];
        // pass 1: inverse C2C along kh per kw bin; the spectrum is
        // already kw-major so the lane gathers are contiguous bn-runs
        for kw in 0..nf {
            for kh in 0..n {
                let src = (kw * n + kh) * batch + b0;
                work_re[kh * bn..(kh + 1) * bn]
                    .copy_from_slice(&spec_re[src..src + bn]);
                work_im[kh * bn..(kh + 1) * bn]
                    .copy_from_slice(&spec_im[src..src + bn]);
            }
            soa::cfft_batch(self, work_re, work_im, bn, true);
            for r in 0..clip_h {
                let dst = (r * nf + kw) * bn;
                rows_re[dst..dst + bn]
                    .copy_from_slice(&work_re[r * bn..(r + 1) * bn]);
                rows_im[dst..dst + bn]
                    .copy_from_slice(&work_im[r * bn..(r + 1) * bn]);
            }
        }
        // pass 2: C2R along rows, two rows per complex inverse (§5.2
        // pack run backwards: Z = A + i·B, Re ← row 2rp, Im ← row 2rp+1)
        let scale = 1.0 / (n * n) as f32;
        let clip = clip_h * clip_w;
        let mut rp = 0;
        while 2 * rp < clip_h {
            let r0 = 2 * rp;
            let r1 = r0 + 1;
            let paired = r1 < clip_h;
            for k in 0..n {
                let (src, conj) = if k < nf { (k, false) } else { (n - k, true) };
                let a = (r0 * nf + src) * bn;
                let wr = &mut work_re[k * bn..(k + 1) * bn];
                let wi = &mut work_im[k * bn..(k + 1) * bn];
                let sgn = if conj { -1.0f32 } else { 1.0 };
                if paired {
                    let b = (r1 * nf + src) * bn;
                    for l in 0..bn {
                        let (ar, ai) = (rows_re[a + l], sgn * rows_im[a + l]);
                        let (br, bi) = (rows_re[b + l], sgn * rows_im[b + l]);
                        wr[l] = ar - bi;
                        wi[l] = ai + br;
                    }
                } else {
                    for l in 0..bn {
                        wr[l] = rows_re[a + l];
                        wi[l] = sgn * rows_im[a + l];
                    }
                }
            }
            soa::cfft_batch(self, work_re, work_im, bn, true);
            for l in 0..bn {
                let o0 = l * clip + r0 * clip_w;
                for c in 0..clip_w {
                    out_chunk[o0 + c] = work_re[c * bn + l] * scale;
                }
                if paired {
                    let o1 = l * clip + r1 * clip_w;
                    for c in 0..clip_w {
                        out_chunk[o1 + c] = work_im[c * bn + l] * scale;
                    }
                }
            }
            rp += 1;
        }
    }

    /// Batched 2-D C2R from the planar transposed layout, normalized and
    /// clipped — serial convenience over [`FbfftPlan::irfft2_soa_chunk`].
    pub fn irfft2_batch_soa(&self, spec_re: &[f32], spec_im: &[f32],
                            batch: usize, clip_h: usize, clip_w: usize,
                            out: &mut [f32]) {
        let n = self.n;
        let nf = rfft_len(n);
        let mut rows_re = vec![0f32; clip_h * nf * batch];
        let mut rows_im = vec![0f32; clip_h * nf * batch];
        let mut work_re = vec![0f32; n * batch];
        let mut work_im = vec![0f32; n * batch];
        self.irfft2_soa_chunk(spec_re, spec_im, batch, 0, batch, clip_h,
                              clip_w, &mut rows_re, &mut rows_im,
                              &mut work_re, &mut work_im, out);
    }
}

/// Process-wide fbfft plan cache, lock-free: the legal sizes are the
/// powers of two `2..=256`, so the cache is a fixed array indexed by
/// `log2 n` with one `OnceLock` per slot. The threaded pipeline fan-outs
/// call this once per worker per pass — under the old `Mutex<HashMap>`
/// every lookup serialized on one lock; now a warm lookup is a single
/// atomic load.
pub fn cached(n: usize) -> Arc<FbfftPlan> {
    assert!(n.is_power_of_two() && (2..=MAX_N).contains(&n),
            "fbfft supports power-of-two sizes 2..=256, got {n}");
    // array-repeat seed, not a shared value (each slot is its own cell)
    #[allow(clippy::declare_interior_mutable_const)]
    const EMPTY: OnceLock<Arc<FbfftPlan>> = OnceLock::new();
    static CACHE: [OnceLock<Arc<FbfftPlan>>; 8] = [EMPTY; 8];
    let slot = n.trailing_zeros() as usize - 1;
    CACHE[slot].get_or_init(|| Arc::new(FbfftPlan::new(n))).clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::real::{irfft, rfft};

    fn rand_real(len: usize, seed: u64) -> Vec<f32> {
        let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
        (0..len)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                (s as f64 / u64::MAX as f64) as f32 * 2.0 - 1.0
            })
            .collect()
    }

    #[test]
    fn rejects_unsupported_sizes() {
        for n in [0usize, 1, 3, 12, 512] {
            assert!(std::panic::catch_unwind(|| FbfftPlan::new(n)).is_err(),
                    "n={n} should be rejected");
        }
    }

    #[test]
    fn plan_cache_is_per_size_and_rejects_bad_sizes() {
        // every legal size gets exactly one shared plan, including under
        // concurrent first access (the lock-free OnceLock-array cache)
        for n in [2usize, 4, 8, 16, 32, 64, 128, 256] {
            let from_threads: Vec<Arc<FbfftPlan>> =
                std::thread::scope(|s| {
                    let handles: Vec<_> =
                        (0..4).map(|_| s.spawn(move || cached(n))).collect();
                    handles.into_iter().map(|h| h.join().unwrap()).collect()
                });
            for p in &from_threads {
                assert_eq!(p.len(), n);
                assert!(Arc::ptr_eq(p, &from_threads[0]),
                        "n={n}: cache handed out distinct plans");
            }
        }
        for n in [0usize, 3, 12, 512] {
            assert!(std::panic::catch_unwind(|| cached(n)).is_err(),
                    "cached({n}) should panic");
        }
    }

    #[test]
    fn rfft_batch_matches_planner_all_sizes() {
        for n in [2usize, 4, 8, 16, 32, 64, 128, 256] {
            let batch = 5; // odd: exercises the unpaired tail
            let x = rand_real(batch * n, n as u64);
            let plan = FbfftPlan::new(n);
            let mut out = vec![C32::ZERO; batch * (n / 2 + 1)];
            plan.rfft_batch(&x, n, batch, &mut out);
            for b in 0..batch {
                let want = rfft(&x[b * n..(b + 1) * n], n);
                for (k, w) in want.iter().enumerate() {
                    let g = out[b * (n / 2 + 1) + k];
                    assert!((g - *w).abs() < 2e-3 * (n as f32).sqrt(),
                            "n={n} b={b} k={k}: {g:?} vs {w:?}");
                }
            }
        }
    }

    #[test]
    fn implicit_padding_matches_explicit() {
        let (n, n_in, batch) = (32usize, 13usize, 4usize);
        let x = rand_real(batch * n_in, 7);
        let plan = FbfftPlan::new(n);
        let mut got = vec![C32::ZERO; batch * (n / 2 + 1)];
        plan.rfft_batch(&x, n_in, batch, &mut got);
        for b in 0..batch {
            let mut padded = x[b * n_in..(b + 1) * n_in].to_vec();
            padded.resize(n, 0.0);
            let want = rfft(&padded, n);
            for (k, w) in want.iter().enumerate() {
                assert!((got[b * (n / 2 + 1) + k] - *w).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn irfft_batch_round_trip_with_clip() {
        let (n, batch, clip) = (64usize, 7usize, 40usize);
        let x = rand_real(batch * n, 3);
        let plan = FbfftPlan::new(n);
        let nf = n / 2 + 1;
        let mut spec = vec![C32::ZERO; batch * nf];
        plan.rfft_batch(&x, n, batch, &mut spec);
        let mut back = vec![0f32; batch * clip];
        plan.irfft_batch(&spec, batch, clip, &mut back);
        for b in 0..batch {
            for j in 0..clip {
                assert!((back[b * clip + j] - x[b * n + j]).abs() < 1e-3,
                        "b={b} j={j}");
            }
        }
    }

    #[test]
    fn rfft2_transposed_matches_vendor_2d() {
        use crate::fft::fft2d::rfft2;
        let (n, h, w, batch) = (16usize, 11usize, 9usize, 3usize);
        let x = rand_real(batch * h * w, 5);
        let plan = FbfftPlan::new(n);
        let nf = n / 2 + 1;
        let mut out = vec![C32::ZERO; nf * n * batch];
        plan.rfft2_batch_transposed(&x, h, w, batch, &mut out);
        for b in 0..batch {
            let want = rfft2(&x[b * h * w..(b + 1) * h * w], h, w, n);
            for kh in 0..n {
                for kw in 0..nf {
                    let g = out[(kw * n + kh) * batch + b];
                    let wv = want[kh * nf + kw];
                    assert!((g - wv).abs() < 3e-3,
                            "b={b} ({kh},{kw}): {g:?} vs {wv:?}");
                }
            }
        }
    }

    #[test]
    fn irfft2_transposed_round_trip() {
        let (n, h, w, batch) = (16usize, 12usize, 10usize, 4usize);
        let x = rand_real(batch * h * w, 8);
        let plan = FbfftPlan::new(n);
        let nf = n / 2 + 1;
        let mut spec = vec![C32::ZERO; nf * n * batch];
        plan.rfft2_batch_transposed(&x, h, w, batch, &mut spec);
        let mut back = vec![0f32; batch * h * w];
        plan.irfft2_batch_transposed(&spec, batch, h, w, &mut back);
        for (g, o) in back.iter().zip(&x) {
            assert!((g - o).abs() < 2e-3);
        }
    }

    #[test]
    fn phase_split_equals_fused_batch() {
        // the threaded pipeline runs rows-then-columns in two phases and
        // kw chunks; it must reproduce the fused serial batch bitwise
        let (n, h, w, batch) = (16usize, 11usize, 9usize, 5usize);
        let x = rand_real(batch * h * w, 12);
        let plan = FbfftPlan::new(n);
        let nf = n / 2 + 1;
        let mut want = vec![C32::ZERO; nf * n * batch];
        plan.rfft2_batch_transposed(&x, h, w, batch, &mut want);
        let mut rows_all = vec![C32::ZERO; batch * n * nf];
        plan.rfft2_rows(&x, h, w, batch, &mut rows_all);
        let mut got = vec![C32::ZERO; nf * n * batch];
        let split = nf / 2;
        {
            let (lo, hi) = got.split_at_mut(split * n * batch);
            plan.rfft2_cols_transposed(&rows_all, batch, 0, split, lo);
            plan.rfft2_cols_transposed(&rows_all, batch, split,
                                       nf - split, hi);
        }
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(*g, *w);
        }
    }

    #[test]
    fn one_image_inverse_with_dirty_scratch() {
        let (n, h, w, batch) = (16usize, 12usize, 10usize, 3usize);
        let x = rand_real(batch * h * w, 13);
        let plan = FbfftPlan::new(n);
        let nf = n / 2 + 1;
        let mut spec = vec![C32::ZERO; nf * n * batch];
        plan.rfft2_batch_transposed(&x, h, w, batch, &mut spec);
        let mut rows = vec![C32::new(3.0, -9.0); n * nf]; // stale junk
        for b in 0..batch {
            let mut img = vec![0f32; h * w];
            plan.irfft2_one_transposed(&spec, batch, b, h, w, &mut rows,
                                       &mut img);
            for (g, o) in img.iter().zip(&x[b * h * w..(b + 1) * h * w]) {
                assert!((g - o).abs() < 2e-3);
            }
        }
    }

    #[test]
    fn soa_2d_forward_matches_scalar_transposed() {
        // the SoA path follows the scalar operation order exactly, so
        // the planar planes must reproduce the interleaved output
        for (n, h, w, batch) in [(16usize, 11usize, 9usize, 5usize),
                                 (8, 8, 8, 1), (32, 20, 32, 12)] {
            let x = rand_real(batch * h * w, 21 + n as u64);
            let plan = FbfftPlan::new(n);
            let nf = n / 2 + 1;
            let mut want = vec![C32::ZERO; nf * n * batch];
            plan.rfft2_batch_transposed(&x, h, w, batch, &mut want);
            let mut got_re = vec![0f32; nf * n * batch];
            let mut got_im = vec![0f32; nf * n * batch];
            plan.rfft2_batch_soa(&x, h, w, batch, &mut got_re, &mut got_im);
            for (i, wv) in want.iter().enumerate() {
                let g = C32::new(got_re[i], got_im[i]);
                assert!((g - *wv).abs() < 1e-4 * (n as f32),
                        "n={n} h={h} w={w} batch={batch} i={i}: \
                         {g:?} vs {wv:?}");
            }
        }
    }

    #[test]
    fn soa_2d_round_trip_and_chunked_inverse() {
        let (n, h, w, batch) = (16usize, 12usize, 10usize, 11usize);
        let x = rand_real(batch * h * w, 31);
        let plan = FbfftPlan::new(n);
        let nf = n / 2 + 1;
        let mut sr = vec![0f32; nf * n * batch];
        let mut si = vec![0f32; nf * n * batch];
        plan.rfft2_batch_soa(&x, h, w, batch, &mut sr, &mut si);
        // whole-batch inverse round-trips
        let mut back = vec![0f32; batch * h * w];
        plan.irfft2_batch_soa(&sr, &si, batch, h, w, &mut back);
        for (g, o) in back.iter().zip(&x) {
            assert!((g - o).abs() < 2e-3);
        }
        // ragged batch-group chunks reproduce it exactly (the threaded
        // pipeline decomposition), with dirty per-chunk scratch
        let mut chunked = vec![0f32; batch * h * w];
        let mut rows_re = vec![3f32; h * nf * batch];
        let mut rows_im = vec![-9f32; h * nf * batch];
        let mut work_re = vec![1f32; n * batch];
        let mut work_im = vec![2f32; n * batch];
        for (b0, bn) in [(0usize, 3usize), (3, 8)] {
            plan.irfft2_soa_chunk(&sr, &si, batch, b0, bn, h, w,
                                  &mut rows_re, &mut rows_im,
                                  &mut work_re, &mut work_im,
                                  &mut chunked[b0 * h * w
                                      ..(b0 + bn) * h * w]);
        }
        assert_eq!(chunked, back);
    }

    #[test]
    fn soa_phase_split_equals_fused_batch() {
        // row-pair and kw chunking must reproduce the serial SoA batch
        // bitwise — the threaded pipeline depends on it
        let (n, h, w, batch) = (16usize, 13usize, 9usize, 7usize);
        let x = rand_real(batch * h * w, 41);
        let plan = FbfftPlan::new(n);
        let nf = n / 2 + 1;
        let mut want_re = vec![0f32; nf * n * batch];
        let mut want_im = vec![0f32; nf * n * batch];
        plan.rfft2_batch_soa(&x, h, w, batch, &mut want_re, &mut want_im);
        let mut rows_re = vec![0f32; n * nf * batch];
        let mut rows_im = vec![0f32; n * nf * batch];
        let mut work_re = vec![5f32; n * batch];
        let mut work_im = vec![-5f32; n * batch];
        // ragged row-pair chunks: 3 + 5 = n/2 pairs
        for (rp0, rpn) in [(0usize, 3usize), (3, 5)] {
            let c = 2 * rp0 * nf * batch;
            let len = 2 * rpn * nf * batch;
            plan.rfft2_rows_soa(&x, h, w, batch, rp0, rpn,
                                &mut rows_re[c..c + len],
                                &mut rows_im[c..c + len], &mut work_re,
                                &mut work_im);
        }
        let mut got_re = vec![0f32; nf * n * batch];
        let mut got_im = vec![0f32; nf * n * batch];
        for (kw0, kwn) in [(0usize, 4usize), (4, 5)] {
            let c = kw0 * n * batch;
            let len = kwn * n * batch;
            plan.rfft2_cols_soa(&rows_re, &rows_im, batch, kw0, kwn,
                                &mut got_re[c..c + len],
                                &mut got_im[c..c + len]);
        }
        assert_eq!(got_re, want_re);
        assert_eq!(got_im, want_im);
    }

    #[test]
    fn single_row_batch_works() {
        // batch = 1 exercises the unpaired path end to end
        let n = 32;
        let x = rand_real(n, 9);
        let plan = FbfftPlan::new(n);
        let mut spec = vec![C32::ZERO; n / 2 + 1];
        plan.rfft_batch(&x, n, 1, &mut spec);
        let want = rfft(&x, n);
        for (g, w) in spec.iter().zip(&want) {
            assert!((*g - *w).abs() < 1e-3);
        }
        let mut back = vec![0f32; n];
        plan.irfft_batch(&spec, 1, n, &mut back);
        for (g, o) in back.iter().zip(&x) {
            assert!((g - o).abs() < 1e-3);
        }
    }
}
