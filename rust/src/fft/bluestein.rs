//! Bluestein's chirp-z algorithm for sizes whose factorization escapes
//! the radix set — 'the expensive Bluestein algorithm' the paper notes
//! cuFFT falls back to (§3.2). Its cost is what makes the autotuner's
//! smooth-size interpolation worthwhile, so the substrate must have it.
//!
//! `X_k = c_k · (a ⊛ b)_k` with `a_j = x_j·c_j`, `b_j = conj(c_j)` and
//! chirp `c_j = e^{-πi j²/n}`, the circular convolution running on a
//! power-of-two mixed-radix plan of size `m ≥ 2n-1`.

use super::complex::C32;
use super::radix::MixedRadix;

pub struct Bluestein {
    n: usize,
    m: usize,
    inner: MixedRadix,
    /// chirp c_j, j < n (forward sign)
    chirp: Vec<C32>,
    /// FFT of the symmetric chirp kernel b, pre-transformed once
    kernel_f: Vec<C32>,
}

impl Bluestein {
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        let m = (2 * n - 1).next_power_of_two();
        let inner = MixedRadix::new(m);
        // j² mod 2n in integers keeps the chirp angle exact for large j
        let chirp: Vec<C32> = (0..n)
            .map(|j| {
                let jj = ((j as u64 * j as u64) % (2 * n as u64)) as f64;
                let ang = -std::f64::consts::PI * jj / n as f64;
                C32::new(ang.cos() as f32, ang.sin() as f32)
            })
            .collect();
        let mut b = vec![C32::ZERO; m];
        b[0] = chirp[0].conj();
        for j in 1..n {
            b[j] = chirp[j].conj();
            b[m - j] = chirp[j].conj();
        }
        let kernel_f = inner.transform(&b, false);
        Bluestein { n, m, inner, chirp, kernel_f }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    /// Forward (or inverse, unnormalized) DFT of arbitrary size `n`.
    pub fn transform(&self, input: &[C32], inverse: bool) -> Vec<C32> {
        assert_eq!(input.len(), self.n);
        let chirp = |j: usize| {
            if inverse {
                self.chirp[j].conj()
            } else {
                self.chirp[j]
            }
        };
        let mut a = vec![C32::ZERO; self.m];
        for j in 0..self.n {
            a[j] = input[j] * chirp(j);
        }
        let mut af = self.inner.transform(&a, false);
        for (k, v) in af.iter_mut().enumerate() {
            let kf = if inverse {
                self.kernel_f[k].conj()
            } else {
                self.kernel_f[k]
            };
            *v = *v * kf;
        }
        let conv = self.inner.transform(&af, true);
        let scale = 1.0 / self.m as f32;
        (0..self.n).map(|k| conv[k].scale(scale) * chirp(k)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::naive_dft;

    fn rand_signal(n: usize, seed: u64) -> Vec<C32> {
        let mut s = seed.wrapping_mul(0x2545F4914F6CDD1D) | 1;
        let mut next = move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            (s as f64 / u64::MAX as f64) as f32 * 2.0 - 1.0
        };
        (0..n).map(|_| C32::new(next(), next())).collect()
    }

    #[test]
    fn matches_naive_on_primes() {
        for n in [11usize, 13, 17, 23, 31, 61, 127] {
            let x = rand_signal(n, n as u64);
            let bs = Bluestein::new(n);
            let got = bs.transform(&x, false);
            let want = naive_dft(&x, false);
            for (g, w) in got.iter().zip(&want) {
                assert!((*g - *w).abs() < 2e-3,
                        "n={n}: {g:?} vs {w:?}");
            }
        }
    }

    #[test]
    fn matches_naive_on_smooth_sizes_too() {
        // must be algorithm-agnostic correct, not just prime-only
        for n in [6usize, 12, 16] {
            let x = rand_signal(n, 5);
            let bs = Bluestein::new(n);
            let got = bs.transform(&x, false);
            let want = naive_dft(&x, false);
            for (g, w) in got.iter().zip(&want) {
                assert!((*g - *w).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn round_trip_prime() {
        let n = 13;
        let x = rand_signal(n, 3);
        let bs = Bluestein::new(n);
        let f = bs.transform(&x, false);
        let back = bs.transform(&f, true);
        for (b, orig) in back.iter().zip(&x) {
            let b = b.scale(1.0 / n as f32);
            assert!((b - *orig).abs() < 1e-3);
        }
    }
}
