//! Toolchain probe for the SIMD dispatch layer: the AVX-512 `std::arch`
//! intrinsics and `#[target_feature(enable = "avx512f")]` are stable only
//! from rustc 1.89, so the AVX-512 kernel module compiles only when the
//! building toolchain can accept it. The `fbfft_avx512` cfg gates the
//! *code*; runtime feature detection (`util::simd`) still decides whether
//! it ever executes, and the reported dispatch tier stays honest on
//! toolchains where the gate is off (detection caps at `avx2`).

use std::process::Command;

fn main() {
    println!("cargo:rerun-if-changed=build.rs");
    // declare the custom cfg so check-cfg toolchains accept it under
    // `-D warnings`
    println!("cargo:rustc-check-cfg=cfg(fbfft_avx512)");
    let rustc =
        std::env::var("RUSTC").unwrap_or_else(|_| "rustc".to_string());
    let ver = Command::new(&rustc)
        .arg("--version")
        .output()
        .ok()
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .unwrap_or_default();
    if let Some((major, minor)) = parse_version(&ver) {
        if (major, minor) >= (1, 89) {
            println!("cargo:rustc-cfg=fbfft_avx512");
        }
    }
}

/// Parse `rustc 1.89.0 (…)` → `(1, 89)`. Unparseable output (unusual
/// wrappers, future formats) leaves the AVX-512 gate off — safe default.
fn parse_version(s: &str) -> Option<(u32, u32)> {
    let tok = s.split_whitespace().nth(1)?;
    let mut parts = tok.split(['.', '-', '+']);
    let major = parts.next()?.parse().ok()?;
    let minor = parts.next()?.parse().ok()?;
    Some((major, minor))
}
