//! Serving-engine tier: batcher admission invariants, the persistent
//! strategy cache, and the multi-shard soak. Everything here runs on
//! the host-engine backend — no artifacts or PJRT needed — so this
//! tier always executes (the PJRT serving path is covered by the
//! artifact-gated `integration.rs`).

use std::collections::HashSet;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use fbfft_repro::conv::ConvProblem;
use fbfft_repro::coordinator::batcher::{Batcher, BatcherConfig};
use fbfft_repro::coordinator::service::{Completion, EngineConfig,
                                        ServeEngine, ServeRequest};
use fbfft_repro::coordinator::{Pass, Strategy, StrategyCache};
use fbfft_repro::reports::serve_json;
use fbfft_repro::util::{Json, Rng};

fn cfg(cap: usize, wait_ms: u64) -> BatcherConfig {
    BatcherConfig { capacity: cap,
                    max_wait: Duration::from_millis(wait_ms) }
}

// ---------------------------------------------------------------------------
// Batcher admission path
// ---------------------------------------------------------------------------

#[test]
fn batcher_orders_flushes_by_deadline_not_arrival() {
    let mut b = Batcher::new(cfg(4, 1000));
    let t = Instant::now();
    let ms = |n: u64| t + Duration::from_millis(n);
    // arrival order 1,2,3 — deadline order 3,1,2
    b.push_deadline(1, 4, t, ms(50));
    b.push_deadline(2, 4, t, ms(80));
    b.push_deadline(3, 4, t, ms(10));
    assert_eq!(b.deadline(), Some(ms(10)), "most urgent leads");
    let order: Vec<u64> = std::iter::from_fn(|| {
        let batch = b.drain();
        batch.parts.first().map(|(id, _)| *id)
    })
    .collect();
    assert_eq!(order, vec![3, 1, 2]);
}

#[test]
fn batcher_deadline_poll_flushes_only_expired_urgency() {
    let mut b = Batcher::new(cfg(64, 1000));
    let t = Instant::now();
    b.push_deadline(1, 1, t, t + Duration::from_millis(5));
    b.push_deadline(2, 1, t, t + Duration::from_millis(500));
    assert!(b.poll(t).is_none(), "nothing expired yet");
    let batch = b
        .poll(t + Duration::from_millis(6))
        .expect("urgent deadline expired");
    // a timeout flush takes the whole queue up to capacity
    assert_eq!(batch.parts, vec![(1, 1), (2, 1)]);
    assert_eq!(b.flushes_timeout, 1);
}

#[test]
fn batcher_splits_oversized_requests_across_batches() {
    let mut b = Batcher::new(cfg(8, 0));
    let t = Instant::now();
    b.push(1, 35, t); // >4x capacity
    let mut sizes = Vec::new();
    loop {
        let batch = b.drain();
        if batch.is_empty() {
            break;
        }
        assert!(batch.images() <= 8);
        sizes.push(batch.images());
    }
    assert_eq!(sizes, vec![8, 8, 8, 8, 3]);
}

#[test]
fn batcher_handles_ragged_final_batches() {
    // the fft_soa.rs ragged batch sizes, one request each
    let sizes = [1usize, 7, 8, 9, 35];
    let mut b = Batcher::new(cfg(8, 0));
    let t = Instant::now();
    for (id, n) in sizes.iter().enumerate() {
        b.push(id as u64, *n, t);
    }
    let total: usize = sizes.iter().sum();
    let mut drained = 0usize;
    let mut batches = 0usize;
    loop {
        let batch = b.drain();
        if batch.is_empty() {
            break;
        }
        assert!(batch.images() >= 1 && batch.images() <= 8);
        drained += batch.images();
        batches += 1;
    }
    assert_eq!(drained, total, "images conserved across ragged batches");
    // 60 images at capacity 8 → at least ceil(60/8) batches
    assert!(batches >= 8, "{batches} batches");
    assert!(b.is_empty());
}

// ---------------------------------------------------------------------------
// Strategy cache through the engine
// ---------------------------------------------------------------------------

#[test]
fn engine_persists_and_warm_loads_the_strategy_cache() {
    let tmp = std::env::temp_dir().join("fbfft_serve_tune_test.json");
    std::fs::remove_file(&tmp).ok();
    let p = ConvProblem::square(4, 1, 1, 8, 3);
    let engine_cfg = || EngineConfig {
        shards: 1,
        batcher: cfg(4, 1),
        default_deadline: Duration::from_secs(60),
        tuner_path: Some(tmp.clone()),
        ..Default::default()
    };
    let run_once = || {
        let engine = ServeEngine::start_host(p, engine_cfg()).unwrap();
        // sequential closed loop: each request flushes alone, so both
        // runs exercise exactly the shapes s ∈ {1, 2, 3}
        for id in 0..3u64 {
            let (tx, rx) = mpsc::channel::<Completion>();
            assert!(engine.submit(ServeRequest {
                id,
                images: 1 + id as usize,
                deadline: None,
                reply: tx,
            }).is_ok());
            rx.recv_timeout(Duration::from_secs(30))
                .expect("request served");
        }
        engine.shutdown()
    };
    let first = run_once();
    assert!(first.cache.entries > 0, "cache populated: {:?}",
            first.cache);
    assert!(first.cache.tunes > 0, "cold start tunes");
    assert!(tmp.exists(), "cache persisted at shutdown");
    // warm restart: same shapes, zero tuner runs
    let second = run_once();
    assert_eq!(second.cache.tunes, 0,
               "warm-loaded cache serves without re-tuning: {:?}",
               second.cache);
    assert!(second.cache.hits > 0);
    std::fs::remove_file(&tmp).ok();
}

#[test]
fn warm_cache_lookup_is_populated_for_flush_shapes() {
    let p = ConvProblem::square(8, 1, 1, 8, 3);
    let engine = ServeEngine::start_host(
        p,
        EngineConfig {
            shards: 1,
            batcher: cfg(8, 1),
            ..Default::default()
        })
        .unwrap();
    // startup warming covers the singleton and the full batch
    let cache: &StrategyCache = engine.cache();
    for s in [1usize, 8] {
        let q = ConvProblem { s, ..p };
        assert!(cache.lookup(&q, Pass::Fprop).is_some(),
                "warm shape s={s} missing");
    }
    engine.shutdown();
}

// ---------------------------------------------------------------------------
// Multi-shard soak
// ---------------------------------------------------------------------------

/// ISSUE 5 acceptance: N>=4 shards, >=500 requests with mixed and
/// oversized sizes, zero lost or duplicated completions, and the
/// serve report carries aggregate p99 plus per-shard histograms.
#[test]
fn soak_four_shards_exactly_once_and_reported() {
    const SHARDS: usize = 4;
    const SUBMITTERS: usize = 4;
    const PER_THREAD: usize = 130; // 520 total
    let sizes = [1usize, 7, 8, 9, 35, 2, 4, 3];
    let p = ConvProblem::square(8, 2, 2, 8, 3);
    let engine = ServeEngine::start_host(
        p,
        EngineConfig {
            shards: SHARDS,
            batcher: cfg(8, 1),
            default_deadline: Duration::from_secs(120),
            ..Default::default()
        })
        .unwrap();
    let t0 = Instant::now();
    let mut per_thread: Vec<(usize, Vec<Completion>)> = Vec::new();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for w in 0..SUBMITTERS {
            let client = engine.client();
            handles.push(scope.spawn(move || {
                let (tx, rx) = mpsc::channel::<Completion>();
                let mut submitted_images = 0usize;
                for i in 0..PER_THREAD {
                    let images = sizes[(w + i) % sizes.len()];
                    let accepted = client.submit(ServeRequest {
                        id: ((w as u64) << 32) | i as u64,
                        images,
                        deadline: None,
                        reply: tx.clone(),
                    });
                    assert!(accepted.is_ok(), "soak load must not be shed");
                    submitted_images += images;
                }
                drop(tx);
                let mut got = Vec::new();
                while let Ok(c) =
                    rx.recv_timeout(Duration::from_secs(60))
                {
                    got.push(c);
                    if got.len() == PER_THREAD {
                        break;
                    }
                }
                (submitted_images, got)
            }));
        }
        for h in handles {
            per_thread.push(h.join().expect("submitter panicked"));
        }
    });
    let wall = t0.elapsed();

    // exactly-once: every request completed, no id twice, image counts
    // preserved end to end (oversized requests reassembled from splits)
    let mut seen = HashSet::new();
    let mut total_images = 0usize;
    let mut expected_images = 0usize;
    for (submitted, completions) in &per_thread {
        expected_images += submitted;
        assert_eq!(completions.len(), PER_THREAD,
                   "every request completes");
        for c in completions {
            assert!(seen.insert(c.id), "duplicate completion {}", c.id);
            assert!(c.shard < SHARDS);
            total_images += c.images;
        }
    }
    assert_eq!(seen.len(), SUBMITTERS * PER_THREAD);
    assert_eq!(total_images, expected_images,
               "split requests report their full image count");

    let report = engine.shutdown();
    assert_eq!(report.shards.len(), SHARDS);
    assert_eq!(report.requests(), SUBMITTERS * PER_THREAD);
    assert_eq!(report.images(), expected_images);
    assert_eq!(report.rejected_deadline, 0);
    assert_eq!(report.launch_errors(), 0,
               "host backend launches never fail");
    for s in &report.shards {
        assert!(s.requests > 0,
                "least-loaded routing spreads over shard {}", s.shard);
        assert!(s.launches > 0);
        assert!(s.batch_fill > 0.0 && s.batch_fill <= 1.0);
        // every launch reconciles to exactly one flush reason — the
        // `flushes_drain` counter closes the shutdown-path gap
        assert_eq!(s.launches,
                   s.flushes_full + s.flushes_timeout + s.flushes_drain,
                   "shard {}: launches must equal full+timeout+drain",
                   s.shard);
        // supervision ledger: every admitted request resolves
        assert_eq!(s.requests_completed + s.requests_failed, s.requests,
                   "shard {}: completed+failed must equal requests",
                   s.shard);
        assert_eq!(s.requests_failed, 0, "clean soak fails nothing");
        assert_eq!(s.restarts, 0);
        assert!(!s.circuit_broken);
    }

    // the reports::serve document carries the acceptance keys
    let j = serve_json(&report, "soak", false, wall);
    let agg = j.get("aggregate").expect("aggregate block");
    let p99 = agg.get("p99_ms").and_then(Json::as_f64)
        .expect("aggregate p99");
    assert!(p99 > 0.0);
    assert_eq!(agg.get("count").and_then(Json::as_usize),
               Some(SUBMITTERS * PER_THREAD));
    let shards = j.get("per_shard").and_then(Json::as_arr)
        .expect("per-shard rows");
    assert_eq!(shards.len(), SHARDS);
    for s in shards {
        for k in ["p50_ms", "p95_ms", "p99_ms", "batch_fill",
                  "queue_depth_max", "flushes_drain", "spectra_hits",
                  "spectra_misses", "spectra_invalidated",
                  "weight_fft_ns", "completed", "requests_failed",
                  "restarts", "degraded_flushes", "faults_injected",
                  "circuit_broken"] {
            assert!(s.get(k).and_then(Json::as_f64).is_some(),
                    "per-shard key {k} missing");
        }
    }
    assert_eq!(j.get("rejected_deadline").and_then(Json::as_usize),
               Some(0));
    // schema v4: spectrum-cache, supervision, and net-chain accounting
    assert_eq!(j.get("version").and_then(Json::as_f64), Some(4.0));
    assert_eq!(j.get("weights_version").and_then(Json::as_usize),
               Some(1), "no bump issued during the soak");
    for k in ["spectra_hits", "spectra_misses", "spectra_invalidated",
              "weight_fft_ns", "weight_fft_last_ns", "completed",
              "requests_failed", "rejected_unavailable",
              "shard_restarts", "degraded_flushes", "faults_injected",
              "circuit_broken", "states_per_sec", "pack_overlap_ns",
              "pack_wait_ns"] {
        assert!(j.get(k).and_then(Json::as_f64).is_some(),
                "top-level key {k} missing");
    }
    // this engine serves a single-layer plan: one per_layer row whose
    // flush count matches the launch ledger
    assert_eq!(j.get("layers").and_then(Json::as_usize), Some(1));
    let per_layer = j.get("per_layer").and_then(Json::as_arr)
        .expect("per-layer rows");
    assert_eq!(per_layer.len(), 1);
    assert_eq!(per_layer[0].get("count").and_then(Json::as_usize),
               Some(report.launches()),
               "layer-0 latency histogram records every flush");
    assert!(j.get("states_per_sec").and_then(Json::as_f64).unwrap()
            > 0.0);
    // the fault-free soak is a clean run: ledger balances with zero
    // failures and no supervision events
    assert_eq!(j.get("completed").and_then(Json::as_usize),
               Some(SUBMITTERS * PER_THREAD));
    assert_eq!(j.get("requests_failed").and_then(Json::as_usize),
               Some(0));
    assert_eq!(j.get("shard_restarts").and_then(Json::as_usize),
               Some(0));
}

/// Tentpole acceptance at the serving layer: two back-to-back
/// full-capacity flushes forced onto the fbfft path — the first pays
/// the weight FFT (spectrum miss), the second must spend zero
/// weight-FFT time, and a mid-traffic `update_weights` bump
/// invalidates exactly that problem's spectra with traffic continuing
/// uninterrupted.
#[test]
fn weight_bump_invalidates_spectra_without_downtime() {
    const CAP: usize = 8;
    let p = ConvProblem::square(CAP, 2, 2, 8, 3);
    let engine = ServeEngine::start_host(
        p,
        EngineConfig {
            shards: 1,
            batcher: cfg(CAP, 1),
            default_deadline: Duration::from_secs(60),
            warm: false,
            force_strategy: Some(Strategy::Fbfft),
            ..Default::default()
        })
        .unwrap();
    assert_eq!(engine.client().weights_version(), 1);
    let (tx, rx) = mpsc::channel::<Completion>();
    let serve_one = |id: u64| {
        // full-capacity requests flush immediately and alone; the
        // blocking recv serializes the flushes
        assert!(engine.submit(ServeRequest {
            id,
            images: CAP,
            deadline: None,
            reply: tx.clone(),
        }).is_ok());
        let c = rx.recv_timeout(Duration::from_secs(30))
            .expect("flush completes");
        assert_eq!(c.id, id);
    };
    serve_one(0); // miss: builds the v1 spectrum
    serve_one(1); // hit: steady state
    let new_weights = Rng::new(0xB0B).normal_vec(p.weight_len());
    assert_eq!(engine.update_weights(new_weights), Ok(2),
               "bump returns the freshly installed version");
    serve_one(2); // miss: v1 spectrum invalidated, v2 built
    serve_one(3); // hit again at v2
    let report = engine.shutdown();
    assert_eq!(report.requests(), 4);
    assert_eq!(report.launches(), 4);
    assert_eq!(report.launch_errors(), 0, "zero downtime across the bump");
    assert_eq!(report.weights_version(), 2);
    assert_eq!(report.spectra_misses(), 2, "one weight FFT per version");
    assert_eq!(report.spectra_hits(), 2);
    assert_eq!(report.spectra_invalidated(), 1,
               "the bump dropped exactly the stale v1 spectrum");
    // both steady-state flushes skipped the weight FFT entirely
    assert_eq!(report.weight_fft().last(), 0.0,
               "final flush must hit the spectrum cache");
}

/// An idle engine parks on its channel (no deadline spin) and still
/// wakes promptly for late traffic.
#[test]
fn idle_engine_wakes_for_late_requests() {
    let p = ConvProblem::square(4, 1, 1, 8, 3);
    let engine = ServeEngine::start_host(
        p,
        EngineConfig {
            shards: 2,
            batcher: cfg(4, 1),
            warm: false,
            ..Default::default()
        })
        .unwrap();
    std::thread::sleep(Duration::from_millis(120)); // idle period
    let (tx, rx) = mpsc::channel::<Completion>();
    assert!(engine.submit(ServeRequest { id: 9, images: 2,
                                         deadline: None,
                                         reply: tx }).is_ok());
    let c = rx.recv_timeout(Duration::from_secs(30))
        .expect("late request served after idle park");
    assert_eq!(c.id, 9);
    assert_eq!(c.images, 2);
    let report = engine.shutdown();
    assert_eq!(report.requests(), 1);
    assert_eq!(report.launches(), 1);
}
