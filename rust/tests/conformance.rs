//! The cross-engine conformance matrix, run in full by `cargo test`:
//! every host engine × every training pass, validated against the f64
//! oracle (and against each other) over the default suite — adversarial
//! shapes plus seeded Table-2 samples.

use fbfft_repro::coordinator::Pass;
use fbfft_repro::testkit::{cases, matrix, Engine};

#[test]
fn full_conformance_matrix() {
    let suite = cases::conformance_suite();

    // acceptance floor: ≥10 generated problems, a Bluestein-path case,
    // and the tiled decomposition in every row
    assert!(suite.len() >= 10, "suite has only {} cases", suite.len());
    assert!(suite.iter().any(|c| c.forces_bluestein()),
            "no prime/non-smooth vendor basis in the suite");

    let report = matrix::run_suite(&suite);
    // always print the matrix; visible via `cargo test -- --nocapture`
    // and in the failure output
    println!("{}", report.render());

    // 5 engines × 3 passes validated in every case
    for cr in &report.cases {
        assert_eq!(cr.cells.len(), Engine::ALL.len() * Pass::ALL.len(),
                   "{}: incomplete matrix row", cr.name);
        for engine in Engine::ALL {
            for pass in Pass::ALL {
                let cell = cr.cell(engine, pass);
                assert!(cell.max_abs.is_finite(),
                        "{}: {}/{} produced non-finite error", cr.name,
                        engine.tag(), pass.tag());
            }
        }
    }

    assert!(report.all_ok(), "conformance failures:\n{}", report.render());
}

#[test]
fn bluestein_case_really_runs_bluestein() {
    // the adversarial prime cases must exercise the planner's Bluestein
    // algorithm, not mixed-radix
    use fbfft_repro::fft::Plan;
    for c in cases::adversarial_cases() {
        if c.forces_bluestein() {
            assert_eq!(Plan::new(c.vendor_basis).algorithm_name(),
                       "bluestein",
                       "{}: basis {} does not dispatch to Bluestein",
                       c.name, c.vendor_basis);
        }
    }
}

#[test]
fn matrix_report_is_greppable() {
    // one small case end to end through the public API: the rendered
    // report names the case, every engine, and the cross-engine line
    let suite = cases::sampled_cases(0xD0C, 1);
    let report = matrix::run_suite(&suite);
    let text = report.render();
    assert!(text.contains(&suite[0].name));
    for e in Engine::ALL {
        assert!(text.contains(e.tag()));
    }
    assert!(text.contains("cross-engine max deviation"));
    assert!(report.all_ok(), "\n{text}");
}
