//! Property suite for the split-complex (SoA) batch-lane FFT kernels:
//! every fbfft plan size (8–256) × ragged batch counts straddling the
//! SIMD lane width, asserting the SoA kernels match the scalar
//! `cfft_in_place` / `rfft_batch` path within the testkit tolerance
//! model, plus inverse round-trips and the 2-D planar fused-transposed
//! layout against its interleaved twin. (The conformance matrix in
//! `tests/conformance.rs` additionally runs the SoA engine through every
//! conv pass against the f64 oracle.)

use fbfft_repro::fft::fbfft_host::FbfftPlan;
use fbfft_repro::fft::real::rfft_len;
use fbfft_repro::fft::soa::{self, LANES};
use fbfft_repro::fft::C32;
use fbfft_repro::testkit::tolerance;
use fbfft_repro::util::Rng;

const SIZES: [usize; 6] = [8, 16, 32, 64, 128, 256];

fn batches() -> [usize; 5] {
    [1, LANES - 1, LANES, LANES + 1, 4 * LANES + 3]
}

#[test]
fn cfft_batch_matches_scalar_across_sizes_and_ragged_batches() {
    for n in SIZES {
        let plan = FbfftPlan::new(n);
        for batch in batches() {
            let mut rng = Rng::new(0x50A ^ (n * 1000 + batch) as u64);
            let re0 = rng.normal_vec(n * batch);
            let im0 = rng.normal_vec(n * batch);
            for inverse in [false, true] {
                let mut re = re0.clone();
                let mut im = im0.clone();
                soa::cfft_batch(&plan, &mut re, &mut im, batch, inverse);
                let tol = tolerance::fft_abs(n);
                for b in (0..batch).step_by((batch / 3).max(1)) {
                    let mut buf: Vec<C32> = (0..n)
                        .map(|j| C32::new(re0[j * batch + b],
                                          im0[j * batch + b]))
                        .collect();
                    plan.cfft_in_place(&mut buf, inverse);
                    for (j, v) in buf.iter().enumerate() {
                        let g = C32::new(re[j * batch + b],
                                         im[j * batch + b]);
                        assert!((g - *v).abs() <= tol,
                                "n={n} batch={batch} b={b} j={j} \
                                 inverse={inverse}: {g:?} vs {v:?}");
                    }
                }
            }
        }
    }
}

#[test]
fn rfft_batch_soa_matches_scalar_rfft_batch() {
    for n in SIZES {
        let plan = FbfftPlan::new(n);
        let nf = rfft_len(n);
        for batch in batches() {
            let mut rng = Rng::new(0xAB0 ^ (n + batch) as u64);
            let x = rng.normal_vec(batch * n);
            // scalar path: batch-major interleaved
            let mut want = vec![C32::ZERO; batch * nf];
            plan.rfft_batch(&x, n, batch, &mut want);
            // SoA path: bin-major planar
            let mut got_re = vec![0f32; nf * batch];
            let mut got_im = vec![0f32; nf * batch];
            let pairs = batch.div_ceil(2);
            let mut wr = vec![0f32; n * pairs];
            let mut wi = vec![0f32; n * pairs];
            soa::rfft_batch_soa(&plan, &x, n, batch, &mut got_re,
                                &mut got_im, &mut wr, &mut wi);
            let tol = tolerance::fft_abs(n);
            for b in 0..batch {
                for k in 0..nf {
                    let g = C32::new(got_re[k * batch + b],
                                     got_im[k * batch + b]);
                    let w = want[b * nf + k];
                    assert!((g - w).abs() <= tol,
                            "n={n} batch={batch} b={b} k={k}: \
                             {g:?} vs {w:?}");
                }
            }
        }
    }
}

#[test]
fn soa_1d_inverse_round_trips_with_implicit_padding() {
    for n in SIZES {
        let plan = FbfftPlan::new(n);
        let nf = rfft_len(n);
        let n_in = (3 * n) / 4; // exercise the implicit-padding load
        for batch in batches() {
            let mut rng = Rng::new(0x1F ^ (n * 31 + batch) as u64);
            let x = rng.normal_vec(batch * n_in);
            let mut sr = vec![0f32; nf * batch];
            let mut si = vec![0f32; nf * batch];
            let pairs = batch.div_ceil(2);
            let mut wr = vec![0f32; n * pairs];
            let mut wi = vec![0f32; n * pairs];
            soa::rfft_batch_soa(&plan, &x, n_in, batch, &mut sr, &mut si,
                                &mut wr, &mut wi);
            let mut back = vec![0f32; batch * n_in];
            soa::irfft_batch_soa(&plan, &sr, &si, batch, n_in, &mut back,
                                 &mut wr, &mut wi);
            let tol = 2.0 * tolerance::fft_abs(n);
            for (i, (g, o)) in back.iter().zip(&x).enumerate() {
                assert!((g - o).abs() <= tol,
                        "n={n} batch={batch} elem {i}: {g} vs {o}");
            }
        }
    }
}

#[test]
fn soa_2d_planar_matches_interleaved_scalar_2d() {
    for (n, h, w, batch) in [(8usize, 6usize, 7usize, LANES + 1),
                             (16, 16, 16, LANES - 1),
                             (32, 21, 17, 4 * LANES + 3), (64, 40, 64, 1)] {
        let plan = FbfftPlan::new(n);
        let nf = rfft_len(n);
        let mut rng = Rng::new(0x2D ^ (n + batch) as u64);
        let x = rng.normal_vec(batch * h * w);
        let mut want = vec![C32::ZERO; nf * n * batch];
        plan.rfft2_batch_transposed(&x, h, w, batch, &mut want);
        let mut got_re = vec![0f32; nf * n * batch];
        let mut got_im = vec![0f32; nf * n * batch];
        plan.rfft2_batch_soa(&x, h, w, batch, &mut got_re, &mut got_im);
        // two forward passes: double the single-transform budget
        let tol = 2.0 * tolerance::fft_abs(n) * (n as f32).sqrt();
        for (i, wv) in want.iter().enumerate() {
            let g = C32::new(got_re[i], got_im[i]);
            assert!((g - *wv).abs() <= tol,
                    "n={n} batch={batch} bin {i}: {g:?} vs {wv:?}");
        }
        // and the planar inverse round-trips through the fused clip
        let mut back = vec![0f32; batch * h * w];
        plan.irfft2_batch_soa(&got_re, &got_im, batch, h, w, &mut back);
        for (i, (g, o)) in back.iter().zip(&x).enumerate() {
            assert!((g - o).abs() <= tol, "round-trip elem {i}");
        }
    }
}
