//! Forced-dispatch conformance sweeps: the full 5-engine × 3-pass
//! matrix, the SoA lane kernels and the blocked CGEMM re-validated with
//! the SIMD tier pinned to `scalar` and to `avx2` (skipping tiers the
//! host cannot run). The tier override is process-global, so every test
//! here funnels through one file-local mutex — `ForcedTier` holds the
//! lock for the duration and restores default resolution on drop, even
//! on panic. (CI additionally runs the whole test suite under
//! `FBFFT_SIMD=scalar`, which exercises the same paths via the env
//! resolution instead of the override.)

use std::sync::{Mutex, MutexGuard};

use fbfft_repro::conv::{cgemm, Workspace};
use fbfft_repro::coordinator::Pass;
use fbfft_repro::fft::fbfft_host::FbfftPlan;
use fbfft_repro::fft::real::rfft_len;
use fbfft_repro::fft::{soa, C32};
use fbfft_repro::testkit::{cases, matrix, tolerance, Engine};
use fbfft_repro::util::{simd, Rng, SimdTier};

static TIER_LOCK: Mutex<()> = Mutex::new(());

/// RAII pin of the global dispatch tier: locks the sweep mutex, forces
/// the tier, and clears the override when dropped. Returns `None` when
/// the host (or toolchain) cannot run the requested tier — the caller
/// skips, it does not fail.
struct ForcedTier {
    _guard: MutexGuard<'static, ()>,
}

impl ForcedTier {
    fn pin(t: SimdTier) -> Option<ForcedTier> {
        if simd::detected() < t {
            eprintln!("skipping {t}: host detects {}", simd::detected());
            return None;
        }
        // a panicking sibling poisons the mutex but leaves nothing
        // inconsistent behind (Drop cleared its override), so recover
        let guard = TIER_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        simd::set_tier_override(Some(t));
        assert_eq!(simd::tier(), t, "override must take effect");
        Some(ForcedTier { _guard: guard })
    }
}

impl Drop for ForcedTier {
    fn drop(&mut self) {
        simd::set_tier_override(None);
    }
}

/// The tiers the sweep pins: the scalar reference and the AVX2+FMA
/// production tier (AVX-512 rides along when the host offers it).
fn sweep_tiers() -> Vec<SimdTier> {
    let mut tiers = vec![SimdTier::Scalar, SimdTier::Avx2];
    if simd::detected() >= SimdTier::Avx512 {
        tiers.push(SimdTier::Avx512);
    }
    tiers
}

/// Batch counts straddling the SoA lane width without ever aligning to
/// it: 1 (degenerate), 7/9 (one off either side of 8), 35 (many lanes
/// plus a ragged tail).
const RAGGED_BATCHES: [usize; 4] = [1, 7, 9, 35];

#[test]
fn conformance_matrix_holds_at_every_forced_tier() {
    // sampled Table-2 problems through all 5 engines × 3 passes against
    // the f64 oracle, with the dispatch tier pinned — the same checks
    // tests/conformance.rs runs at the detected tier
    let suite = cases::sampled_cases(0x51D, 2);
    for t in sweep_tiers() {
        let Some(_pin) = ForcedTier::pin(t) else { continue };
        let report = matrix::run_suite(&suite);
        for cr in &report.cases {
            assert_eq!(cr.cells.len(),
                       Engine::ALL.len() * Pass::ALL.len(),
                       "tier {t}: incomplete matrix row {}", cr.name);
        }
        assert!(report.all_ok(), "tier {t} conformance failures:\n{}",
                report.render());
    }
}

#[test]
fn soa_lane_kernels_match_scalar_reference_at_every_forced_tier() {
    for t in sweep_tiers() {
        let Some(_pin) = ForcedTier::pin(t) else { continue };
        for n in [16usize, 64] {
            let plan = FbfftPlan::new(n);
            let nf = rfft_len(n);
            let tol = tolerance::fft_abs(n);
            for batch in RAGGED_BATCHES {
                let mut rng =
                    Rng::new(0x51D0 ^ (n * 100 + batch) as u64);
                let x = rng.normal_vec(batch * n);
                // scalar interleaved reference (per-signal transforms)
                let mut want = vec![C32::ZERO; batch * nf];
                plan.rfft_batch(&x, n, batch, &mut want);
                // the dispatched SoA batch-lane path
                let mut got_re = vec![0f32; nf * batch];
                let mut got_im = vec![0f32; nf * batch];
                let pairs = batch.div_ceil(2);
                let mut wr = vec![0f32; n * pairs];
                let mut wi = vec![0f32; n * pairs];
                soa::rfft_batch_soa(&plan, &x, n, batch, &mut got_re,
                                    &mut got_im, &mut wr, &mut wi);
                for b in 0..batch {
                    for k in 0..nf {
                        let g = C32::new(got_re[k * batch + b],
                                         got_im[k * batch + b]);
                        let w = want[b * nf + k];
                        assert!((g - w).abs() <= tol,
                                "tier {t} n={n} batch={batch} b={b} \
                                 k={k}: {g:?} vs {w:?}");
                    }
                }
            }
        }
    }
}

#[test]
fn blocked_cgemm_matches_naive_at_every_forced_tier() {
    // ragged reduction depth (not a multiple of any kernel geometry)
    // and a bin count that threads: the blocked path must agree with
    // the naive triple loop at whatever tier is pinned
    let (bins, s, f, fo) = (18usize, 5usize, 13usize, 11usize);
    for t in sweep_tiers() {
        let Some(_pin) = ForcedTier::pin(t) else { continue };
        for pass in Pass::ALL {
            let sh = cgemm::BinShape::of(pass, s, f, fo);
            let mut rng = Rng::new(0xC6E ^ pass.tag().len() as u64);
            let fa: Vec<C32> = (0..bins * sh.a_len)
                .map(|_| C32::new(rng.normal(), rng.normal()))
                .collect();
            let fb: Vec<C32> = (0..bins * sh.b_len)
                .map(|_| C32::new(rng.normal(), rng.normal()))
                .collect();
            let mut want = vec![C32::ZERO; bins * sh.c_len];
            cgemm::batched_naive(pass, bins, s, f, fo, &fa, &fb,
                                 &mut want);
            let mut got = vec![C32::ZERO; bins * sh.c_len];
            let mut ws = Workspace::new();
            cgemm::batched(pass, bins, s, f, fo, &fa, &fb, &mut got,
                           &mut ws);
            let k = sh.k as f32;
            let tol = 2e-3 * k.sqrt();
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!((*g - *w).abs() <= tol,
                        "tier {t} pass {} c[{i}]: {g:?} vs {w:?}",
                        pass.tag());
            }
        }
    }
}

#[test]
fn forced_scalar_is_bitwise_stable_across_repeats() {
    // the scalar tier is the conformance anchor: two runs of the same
    // SoA transform under a pinned scalar tier must agree bit for bit
    let Some(_pin) = ForcedTier::pin(SimdTier::Scalar) else { return };
    let n = 32usize;
    let plan = FbfftPlan::new(n);
    let nf = rfft_len(n);
    let batch = 9usize; // LANES-unaligned on purpose
    let mut rng = Rng::new(0xB17);
    let x = rng.normal_vec(batch * n);
    let run = |x: &[f32]| {
        let mut re = vec![0f32; nf * batch];
        let mut im = vec![0f32; nf * batch];
        let pairs = batch.div_ceil(2);
        let mut wr = vec![0f32; n * pairs];
        let mut wi = vec![0f32; n * pairs];
        soa::rfft_batch_soa(&plan, x, n, batch, &mut re, &mut im,
                            &mut wr, &mut wi);
        (re, im)
    };
    let (r1, i1) = run(&x);
    let (r2, i2) = run(&x);
    for j in 0..nf * batch {
        assert_eq!(r1[j].to_bits(), r2[j].to_bits(), "re bin {j}");
        assert_eq!(i1[j].to_bits(), i2[j].to_bits(), "im bin {j}");
    }
}
