//! Net-serving tier (PR 8 tentpole): whole-CNN plans behind one
//! admission layer. Covers `NetPlan` construction invariants, the
//! chained flush against a per-layer direct-convolution oracle (exact
//! for the forced-direct chain, tolerance-bounded for fbfft), the
//! net-level engine end to end through the `Ticket` client API with
//! schema-v4 per-layer accounting, the validating `EngineConfig`
//! builder, and per-layer weight-bump isolation. Host backend only.

use std::time::Duration;

use fbfft_repro::conv::{direct, ConvProblem};
use fbfft_repro::coordinator::service::{chain_outputs, Backend,
                                        EngineConfig, ServeEngine};
use fbfft_repro::coordinator::{NetLayer, NetPlan, Pass, Strategy};
use fbfft_repro::testkit::{assert_close, tolerance};
use fbfft_repro::util::Rng;

/// Frequency-path tolerance for chain position `i`: the unit-variance
/// bound scaled by the layer's actual input magnitude (activations
/// grow with each reduction, so later layers carry proportionally
/// larger rounding noise).
fn chain_tol(net: &NetPlan, imgs: usize, layer_input: &[f32],
             i: usize) -> f32 {
    let q = ConvProblem { s: imgs, ..net.layers()[i].problem };
    let energy: f32 =
        layer_input.iter().map(|v| v * v).sum::<f32>()
            / layer_input.len() as f32;
    tolerance::frequency(&q, Pass::Fprop, 16) * energy.sqrt().max(1.0)
}

/// Per-layer reference: the same input run through `direct::fprop`
/// layer by layer — the semantics the chained flush must preserve.
fn oracle(net: &NetPlan, imgs: usize, input: &[f32],
          weights: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let mut x = input.to_vec();
    let mut outs = Vec::new();
    for (l, w) in net.layers().iter().zip(weights) {
        let q = ConvProblem { s: imgs, ..l.problem };
        x = direct::fprop(&q, &x[..q.input_len()], w);
        outs.push(x.clone());
    }
    outs
}

fn chain_fixture(imgs: usize) -> (NetPlan, Vec<f32>, Vec<Vec<f32>>) {
    let net = NetPlan::alexnet_small(imgs);
    let mut rng = Rng::new(0x0E7);
    let input = rng.normal_vec(net.input_len(imgs));
    let weights: Vec<Vec<f32>> = net
        .layers()
        .iter()
        .map(|l| rng.normal_vec(l.problem.weight_len()))
        .collect();
    (net, input, weights)
}

// ---------------------------------------------------------------------------
// NetPlan construction
// ---------------------------------------------------------------------------

#[test]
fn netplan_rejects_inconsistent_chains_at_plan_time() {
    assert!(NetPlan::new(Vec::new()).is_err(), "empty plan");
    // batch mismatch: conv2 declares a different S
    let batch_break = NetPlan::new(vec![
        NetLayer::new("conv1", ConvProblem::square(4, 2, 4, 12, 3)),
        NetLayer::new("conv2", ConvProblem::square(8, 4, 4, 10, 3)),
    ]);
    assert!(batch_break.unwrap_err().contains("batch mismatch"));
    // shape break: conv1 emits 4 channels at 10², conv2 wants 8 at 12²
    let shape_break = NetPlan::new(vec![
        NetLayer::new("conv1", ConvProblem::square(4, 2, 4, 12, 3)),
        NetLayer::new("conv2", ConvProblem::square(4, 8, 4, 12, 3)),
    ]);
    assert!(shape_break.unwrap_err().contains("shape break"));
    // the shipped chains are consistent by construction
    assert_eq!(NetPlan::alexnet(8).len(), 5);
    assert_eq!(NetPlan::alexnet_small(8).len(), 3);
}

#[test]
fn netplan_slab_lengths_follow_the_chain_ends() {
    let net = NetPlan::alexnet_small(8);
    assert_eq!(net.batch(), 8);
    let first = &net.layers()[0].problem;
    let last = &net.layers()[2].problem;
    for imgs in [1usize, 3, 8] {
        assert_eq!(net.input_len(imgs),
                   ConvProblem { s: imgs, ..*first }.input_len());
        assert_eq!(net.output_len(imgs),
                   ConvProblem { s: imgs, ..*last }.output_len());
    }
}

// ---------------------------------------------------------------------------
// Chain semantics vs the layerwise oracle
// ---------------------------------------------------------------------------

#[test]
fn forced_direct_chain_is_bitwise_the_layerwise_oracle() {
    let imgs = 4;
    let (net, input, weights) = chain_fixture(imgs);
    let got = chain_outputs(&net, imgs, &input, &weights,
                            Some(Strategy::Direct));
    let want = oracle(&net, imgs, &input, &weights);
    assert_eq!(got.len(), net.len());
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(g, w, "layer {i} output must be bit-identical — \
                          the chain feeds the same slabs the oracle saw");
    }
}

#[test]
fn fbfft_chain_matches_the_oracle_within_f32_tolerance() {
    let imgs = 4;
    let (net, input, weights) = chain_fixture(imgs);
    let got = chain_outputs(&net, imgs, &input, &weights,
                            Some(Strategy::Fbfft));
    let want = oracle(&net, imgs, &input, &weights);
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        let x = if i == 0 { &input } else { &want[i - 1] };
        assert_close(g, w, chain_tol(&net, imgs, x, i));
    }
}

#[test]
fn tuned_chain_serves_without_forcing_a_strategy() {
    // force=None tunes each layer through a fresh in-memory cache —
    // whatever wins must still be numerically sane
    let imgs = 2;
    let (net, input, weights) = chain_fixture(imgs);
    let got = chain_outputs(&net, imgs, &input, &weights, None);
    let want = oracle(&net, imgs, &input, &weights);
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        let x = if i == 0 { &input } else { &want[i - 1] };
        // whatever won the tune, the frequency bound is the loosest
        assert_close(g, w, chain_tol(&net, imgs, x, i));
    }
}

// ---------------------------------------------------------------------------
// The net-level engine end to end
// ---------------------------------------------------------------------------

#[test]
fn net_engine_serves_tickets_with_per_layer_accounting() {
    let net = NetPlan::alexnet_small(8);
    let cap = net.batch();
    let cfg = EngineConfig::builder()
        .shards(2)
        .capacity(cap)
        .max_wait(Duration::from_millis(1))
        .default_deadline(Duration::from_secs(60))
        .build()
        .expect("valid config");
    let engine =
        ServeEngine::start(Backend::Host, net.clone(), cfg).unwrap();
    let sizes = [1usize, 8, 3, 8, 5, 2, 8, 4, 8, 7];
    let tickets: Vec<_> = sizes
        .iter()
        .map(|&n| engine.submit_images(n, None).expect("admitted"))
        .collect();
    let mut images = 0usize;
    for (t, &n) in tickets.into_iter().zip(&sizes) {
        let c = t
            .wait_timeout(Duration::from_secs(60))
            .expect("every ticket resolves");
        assert_eq!(c.images, n, "split requests report full size");
        assert!(c.error.is_none());
        images += c.images;
    }
    let report = engine.shutdown();
    assert_eq!(report.requests(), sizes.len());
    assert_eq!(report.images(), images);
    assert_eq!(report.requests_failed(), 0);
    assert_eq!(report.launch_errors(), 0);
    // per-layer rows: one per chain position, every flush recorded in
    // every layer's latency histogram
    let layers = report.layer_stats();
    assert_eq!(layers.len(), net.len());
    for (i, (ls, l)) in layers.iter().zip(net.layers()).enumerate() {
        assert_eq!(ls.name, l.name, "row {i} keeps the plan's name");
        assert_eq!(ls.latency.len(), report.launches(),
                   "layer {i} runs once per flush");
        assert_eq!(ls.launch_errors, 0);
    }
    // the submit half packed batch k+1 while batch k's chain ran —
    // the overlap the split worker loop exists to create
    assert!(report.pack_overlap() > Duration::ZERO,
            "packing must overlap chain execution");
}

// ---------------------------------------------------------------------------
// Config surface
// ---------------------------------------------------------------------------

#[test]
fn builder_rejects_configs_that_would_wedge_the_engine() {
    assert!(EngineConfig::builder().build().is_ok(), "defaults pass");
    let bad = [
        EngineConfig::builder().shards(0).build(),
        EngineConfig::builder().capacity(0).build(),
        EngineConfig::builder().max_wait(Duration::ZERO).build(),
        EngineConfig::builder()
            .default_deadline(Duration::ZERO)
            .build(),
        EngineConfig::builder().tuner_reps(0).build(),
        EngineConfig::builder().max_consecutive_failures(0).build(),
    ];
    for (i, b) in bad.iter().enumerate() {
        assert!(b.is_err(), "bad config {i} must not build");
    }
    assert!(EngineConfig::builder().shards(0).build().unwrap_err()
              .contains("shards"),
            "errors name the offending knob");
}

#[test]
fn start_rejects_unsupported_backend_and_pass_combinations() {
    let net = NetPlan::alexnet_small(4);
    // gradient passes chain in reverse order — not a serving path
    let grad = EngineConfig::builder()
        .shards(1)
        .capacity(4)
        .pass(Pass::Bprop)
        .build()
        .unwrap();
    assert!(ServeEngine::start(Backend::Host, net.clone(), grad)
              .is_err());
    // PJRT artifacts are compiled per layer shape; multi-layer plans
    // are host-only until a chained artifact exists
    let cfg = EngineConfig::builder().shards(1).capacity(4).build()
        .unwrap();
    assert!(ServeEngine::start(
        Backend::Pjrt { dir: "artifacts".into(),
                        artifact: "conv.quickstart.fbfft.fprop".into() },
        net,
        cfg)
        .is_err());
}

// ---------------------------------------------------------------------------
// Per-layer weight-bump isolation
// ---------------------------------------------------------------------------

#[test]
fn layer_weight_bump_invalidates_only_that_layers_spectra() {
    let net = NetPlan::alexnet_small(8);
    let cap = net.batch();
    let cfg = EngineConfig::builder()
        .shards(1)
        .capacity(cap)
        .max_wait(Duration::from_millis(1))
        .default_deadline(Duration::from_secs(60))
        .warm(false)
        .force_strategy(Strategy::Fbfft)
        .build()
        .unwrap();
    let engine =
        ServeEngine::start(Backend::Host, net.clone(), cfg).unwrap();
    let serve_one = |id: u64| {
        // full-capacity tickets flush immediately and alone; the
        // blocking wait serializes the flushes
        let t = engine.submit_images(cap, None).expect("admitted");
        let c = t.wait_timeout(Duration::from_secs(30))
            .expect("flush completes");
        assert!(c.error.is_none(), "flush {id} serves cleanly");
    };
    serve_one(0); // miss on every layer: three v1 spectra built
    serve_one(1); // hit on every layer
    let w1 = Rng::new(0xB1)
        .normal_vec(net.layers()[1].problem.weight_len());
    assert_eq!(engine.update_layer_weights(1, w1), Ok(2),
               "bump returns layer 1's freshly installed version");
    assert_eq!(engine.client().layer_weights_version(1), 2);
    assert_eq!(engine.client().layer_weights_version(0), 1,
               "other chain positions keep their version");
    serve_one(2); // conv2 rebuilds at v2; conv1/conv3 still hit
    let report = engine.shutdown();
    assert_eq!(report.requests(), 3);
    assert_eq!(report.launch_errors(), 0);
    let layers = report.layer_stats();
    assert_eq!(layers.len(), 3);
    for (i, ls) in layers.iter().enumerate() {
        if i == 1 {
            assert_eq!((ls.spectra_misses, ls.spectra_hits,
                        ls.spectra_invalidated),
                       (2, 1, 1),
                       "the bumped layer rebuilds exactly once");
        } else {
            assert_eq!((ls.spectra_misses, ls.spectra_hits,
                        ls.spectra_invalidated),
                       (1, 2, 0),
                       "layer {i} must not see the bump");
        }
    }
}
