//! Property-based tests over the host substrates (proptest is not
//! available offline; these are seeded randomized properties with many
//! cases per invariant — same coverage philosophy, deterministic replay
//! via the case index).

use fbfft_repro::conv::{direct, im2col, tiled, ConvProblem, FftConvEngine,
                        FftMode};
use fbfft_repro::coordinator::autotuner::candidate_bases;
use fbfft_repro::coordinator::{Batcher, BatcherConfig};
use fbfft_repro::fft::{fbfft_host, is_smooth, naive_dft, plan, real, C32};
use fbfft_repro::testkit::cases::random_small_problem as rand_problem;
use fbfft_repro::util::{Json, Rng};

const CASES: usize = 40;

// ---------------------------------------------------------------------------
// FFT invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_plan_matches_naive_dft_any_size() {
    for case in 0..CASES {
        let mut rng = Rng::new(case as u64);
        let n = rng.int_in(1, 48);
        let x: Vec<C32> = (0..n)
            .map(|_| C32::new(rng.normal(), rng.normal()))
            .collect();
        let got = plan::cached(n).transform(&x, plan::Direction::Forward);
        let want = naive_dft(&x, false);
        for (k, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((*g - *w).abs() < 1e-2 * (n as f32).sqrt(),
                    "case {case} n={n} k={k}: {g:?} vs {w:?}");
        }
    }
}

#[test]
fn prop_fft_round_trip_and_parseval() {
    for case in 0..CASES {
        let mut rng = Rng::new(1000 + case as u64);
        let n = rng.int_in(2, 64);
        let x: Vec<C32> = (0..n)
            .map(|_| C32::new(rng.normal(), rng.normal()))
            .collect();
        let p = plan::cached(n);
        let f = p.transform(&x, plan::Direction::Forward);
        // Parseval: ||x||² = ||F||²/n
        let ex: f64 = x.iter().map(|c| c.norm_sq() as f64).sum();
        let ef: f64 =
            f.iter().map(|c| c.norm_sq() as f64).sum::<f64>() / n as f64;
        assert!((ex - ef).abs() < 1e-2 * ex.max(1.0),
                "case {case} n={n}: {ex} vs {ef}");
        let back = p.inverse_normalized(&f);
        for (b, o) in back.iter().zip(&x) {
            assert!((*b - *o).abs() < 1e-3, "case {case} n={n}");
        }
    }
}

#[test]
fn prop_rfft_hermitian_consistency() {
    // the half-spectrum of a real signal determines the full one: check
    // against the complex transform of the same signal
    for case in 0..CASES {
        let mut rng = Rng::new(2000 + case as u64);
        let n = rng.int_in(2, 64);
        let x = rng.normal_vec(n);
        let half = real::rfft(&x, n);
        let z: Vec<C32> = x.iter().map(|v| C32::new(*v, 0.0)).collect();
        let full = plan::cached(n).transform(&z, plan::Direction::Forward);
        for k in 0..half.len() {
            assert!((half[k] - full[k]).abs() < 2e-3,
                    "case {case} n={n} k={k}");
        }
    }
}

#[test]
fn prop_fbfft_implicit_pad_equals_vendor_explicit_pad() {
    for case in 0..CASES {
        let mut rng = Rng::new(3000 + case as u64);
        let n = *rng.choice(&[8usize, 16, 32, 64]);
        let n_in = rng.int_in(1, n);
        let batch = rng.int_in(1, 6);
        let x = rng.normal_vec(batch * n_in);
        let fb = fbfft_host::cached(n);
        let nf = n / 2 + 1;
        let mut got = vec![C32::ZERO; batch * nf];
        fb.rfft_batch(&x, n_in, batch, &mut got);
        for b in 0..batch {
            let mut padded = x[b * n_in..(b + 1) * n_in].to_vec();
            padded.resize(n, 0.0);
            let want = real::rfft(&padded, n);
            for k in 0..nf {
                assert!((got[b * nf + k] - want[k]).abs() < 2e-3,
                        "case {case} n={n} n_in={n_in} b={b} k={k}");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Convolution invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_all_engines_agree_on_fprop() {
    for case in 0..CASES {
        let mut rng = Rng::new(4000 + case as u64);
        let p = rand_problem(&mut rng, 12);
        let x = rng.normal_vec(p.input_len());
        let w = rng.normal_vec(p.weight_len());
        let a = direct::fprop(&p, &x, &w);
        let b = im2col::fprop(&p, &x, &w);
        let n = p.h.max(p.w).next_power_of_two();
        let (c, _) = FftConvEngine::new(FftMode::Fbfft, n).fprop(&p, &x, &w);
        let (d, _) = FftConvEngine::new(FftMode::Vendor, n).fprop(&p, &x, &w);
        for i in 0..a.len() {
            assert!((a[i] - b[i]).abs() < 1e-3, "case {case} im2col@{i}");
            assert!((a[i] - c[i]).abs() < 5e-3, "case {case} fbfft@{i}");
            assert!((a[i] - d[i]).abs() < 5e-3, "case {case} vendor@{i}");
        }
    }
}

#[test]
fn prop_adjoint_trilinear_chain() {
    // ⟨fprop(x,w), go⟩ == ⟨x, bprop(go,w)⟩ == ⟨w, accgrad(go,x)⟩
    for case in 0..CASES {
        let mut rng = Rng::new(5000 + case as u64);
        let p = rand_problem(&mut rng, 12);
        let x = rng.normal_vec(p.input_len());
        let w = rng.normal_vec(p.weight_len());
        let go = rng.normal_vec(p.output_len());
        let eng = FftConvEngine::fbfft_for(&p);
        let (y, _) = eng.fprop(&p, &x, &w);
        let (gx, _) = eng.bprop(&p, &go, &w);
        let (gw, _) = eng.accgrad(&p, &go, &x);
        let dot = |u: &[f32], v: &[f32]| -> f64 {
            u.iter().zip(v).map(|(a, b)| (*a * *b) as f64).sum()
        };
        let a = dot(&y, &go);
        let b = dot(&x, &gx);
        let c = dot(&w, &gw);
        let tol = 1e-2 * a.abs().max(1.0);
        assert!((a - b).abs() < tol, "case {case}: {a} vs {b}");
        assert!((a - c).abs() < tol, "case {case}: {a} vs {c}");
    }
}

#[test]
fn prop_tiling_invariant_any_tile_size() {
    for case in 0..20 {
        let mut rng = Rng::new(6000 + case as u64);
        let p = ConvProblem::square(rng.int_in(1, 2), rng.int_in(1, 3),
                                    rng.int_in(1, 3), rng.int_in(8, 20), 3);
        let d = rng.int_in(2, p.yh());
        let x = rng.normal_vec(p.input_len());
        let w = rng.normal_vec(p.weight_len());
        let want = direct::fprop(&p, &x, &w);
        let (got, _) = tiled::fprop(&p, &x, &w, d);
        for i in 0..want.len() {
            assert!((got[i] - want[i]).abs() < 5e-3,
                    "case {case} d={d} @{i}");
        }
    }
}

#[test]
fn prop_conv_linearity_in_input() {
    for case in 0..20 {
        let mut rng = Rng::new(7000 + case as u64);
        let p = rand_problem(&mut rng, 10);
        let x1 = rng.normal_vec(p.input_len());
        let x2 = rng.normal_vec(p.input_len());
        let w = rng.normal_vec(p.weight_len());
        let sum: Vec<f32> =
            x1.iter().zip(&x2).map(|(a, b)| a + b).collect();
        let y1 = direct::fprop(&p, &x1, &w);
        let y2 = direct::fprop(&p, &x2, &w);
        let ys = direct::fprop(&p, &sum, &w);
        for i in 0..ys.len() {
            assert!((ys[i] - y1[i] - y2[i]).abs() < 1e-3, "case {case}");
        }
    }
}

// ---------------------------------------------------------------------------
// Coordinator invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_batcher_conserves_and_bounds_images() {
    for case in 0..CASES {
        let mut rng = Rng::new(8000 + case as u64);
        let cap = rng.int_in(1, 16);
        let mut b = Batcher::new(BatcherConfig {
            capacity: cap,
            max_wait: std::time::Duration::ZERO,
        });
        let t = std::time::Instant::now();
        let mut pushed = 0usize;
        for id in 0..rng.int_in(1, 30) as u64 {
            let imgs = rng.int_in(1, 10);
            b.push(id, imgs, t);
            pushed += imgs;
        }
        let mut drained = 0usize;
        let mut last_ids: Vec<u64> = Vec::new();
        loop {
            let batch = b.drain();
            if batch.is_empty() {
                break;
            }
            assert!(batch.images() <= cap, "case {case}: batch too big");
            for (id, n) in &batch.parts {
                assert!(*n >= 1);
                // non-decreasing id order across the whole drain sequence
                if let Some(last) = last_ids.last() {
                    assert!(id >= last, "case {case}: reordered");
                }
                last_ids.push(*id);
                drained += n;
            }
        }
        assert_eq!(drained, pushed, "case {case}: images lost");
    }
}

#[test]
fn prop_candidate_bases_sound() {
    for n in 1..300usize {
        let c = candidate_bases(n);
        assert!(!c.is_empty(), "no candidates for {n}");
        assert_eq!(*c.last().unwrap(), n.next_power_of_two());
        for i in &c {
            assert!(is_smooth(*i) && *i >= n && *i <= n.next_power_of_two());
        }
    }
}

#[test]
fn prop_json_round_trip_random_values() {
    fn rand_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.normal() * 100.0).round() as f64),
            3 => Json::Str(format!("s{}", rng.below(1000))),
            4 => Json::Arr((0..rng.below(4))
                .map(|_| rand_json(rng, depth - 1)).collect()),
            _ => Json::Obj((0..rng.below(4))
                .map(|i| (format!("k{i}"), rand_json(rng, depth - 1)))
                .collect()),
        }
    }
    for case in 0..CASES {
        let mut rng = Rng::new(9000 + case as u64);
        let j = rand_json(&mut rng, 3);
        let text = j.to_string();
        let back = Json::parse(&text)
            .unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        assert_eq!(back, j, "case {case}");
    }
}
