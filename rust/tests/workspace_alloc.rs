//! The zero-allocation pipeline gate: after one warmup round per problem
//! shape, the `_into` passes must never take a new buffer from the heap
//! — every checkout is a reuse of pooled capacity, proven by the
//! `BufferPool` allocation/expansion counters staying flat while the
//! reuse counter climbs. (Vendor-planner internals may still allocate
//! like cuFFT's own workspace does; the pool counters gate every tensor
//! the pipeline itself owns.)

use std::time::Duration;

use fbfft_repro::conv::{ConvProblem, FftConvEngine, FftMode,
                        SpectrumCache, SpectrumPrecision, Workspace};
use fbfft_repro::coordinator::service::{Backend, EngineConfig,
                                        ServeEngine};
use fbfft_repro::coordinator::{NetPlan, Pass};
use fbfft_repro::testkit::{assert_close_oracle, oracle, tolerance};
use fbfft_repro::util::Rng;

#[allow(clippy::too_many_arguments)]
fn run_all_passes(eng: &FftConvEngine, p: &ConvProblem, x: &[f32],
                  wei: &[f32], go: &[f32], y: &mut [f32],
                  gx: &mut [f32], gw: &mut [f32], ws: &mut Workspace) {
    eng.fprop_into(p, x, wei, y, ws);
    eng.bprop_into(p, go, wei, gx, ws);
    eng.accgrad_into(p, go, x, gw, ws);
}

fn zero_alloc_steady_state(mode: FftMode, p: &ConvProblem, n: usize) {
    let mut rng = Rng::new(0xA110C ^ n as u64);
    let x = rng.normal_vec(p.input_len());
    let wei = rng.normal_vec(p.weight_len());
    let go = rng.normal_vec(p.output_len());
    let mut y = vec![0f32; p.output_len()];
    let mut gx = vec![0f32; p.input_len()];
    let mut gw = vec![0f32; p.weight_len()];
    let eng = FftConvEngine::new(mode, n);
    let mut ws = Workspace::new();

    // warmup: every role reaches its high-water mark across all passes
    run_all_passes(&eng, p, &x, &wei, &go, &mut y, &mut gx, &mut gw,
                   &mut ws);
    assert!(ws.pool.allocations > 0,
            "the pipeline must actually use the pool");

    // steady state measured in isolation: reset after warmup, then the
    // counters prove no checkout touched the heap
    ws.pool.reset_counters();
    for _ in 0..3 {
        run_all_passes(&eng, p, &x, &wei, &go, &mut y, &mut gx, &mut gw,
                       &mut ws);
    }
    assert_eq!(ws.pool.allocations, 0,
               "{mode:?}: steady-state pass allocated a new pool buffer");
    assert_eq!(ws.pool.expansions, 0,
               "{mode:?}: steady-state pass grew a pool buffer");
    assert!(ws.pool.reuses > 0,
            "{mode:?}: steady-state passes must reuse pooled buffers");

    // and the reused-buffer outputs are still the right answers
    assert_close_oracle(&y, &oracle::fprop64(p, &x, &wei),
                        tolerance::frequency(p, Pass::Fprop, n));
    assert_close_oracle(&gx, &oracle::bprop64(p, &go, &wei),
                        tolerance::frequency(p, Pass::Bprop, n));
    assert_close_oracle(&gw, &oracle::accgrad64(p, &go, &x),
                        tolerance::frequency(p, Pass::AccGrad, n));
}

#[test]
fn fbfft_acceptance_config_is_zero_alloc_after_warmup() {
    // the acceptance-criteria config: S=16, f=f'=16, 32×32 input, n=32
    let p = ConvProblem::square(16, 16, 16, 32, 5);
    zero_alloc_steady_state(FftMode::Fbfft, &p, 32);
}

#[test]
fn vendor_acceptance_config_is_zero_alloc_after_warmup() {
    let p = ConvProblem::square(16, 16, 16, 32, 5);
    zero_alloc_steady_state(FftMode::Vendor, &p, 32);
}

#[test]
fn fbfft_scalar_acceptance_config_is_zero_alloc_after_warmup() {
    // the scalar baseline's extra PACK staging ("stage.inv", the planar
    // splits) must pool-reuse like everything else
    let p = ConvProblem::square(16, 16, 16, 32, 5);
    zero_alloc_steady_state(FftMode::FbfftScalar, &p, 32);
}

#[test]
fn small_ragged_config_is_zero_alloc_after_warmup() {
    // ragged dims exercise different role sizes per pass
    let p = ConvProblem::new(3, 5, 7, 13, 11, 5, 3);
    zero_alloc_steady_state(FftMode::Fbfft, &p, 16);
}

#[test]
fn spec_path_is_zero_alloc_after_warmup_across_batch_sizes() {
    // the serving steady state: cached weight spectrum, mixed batch
    // sizes. The spectrum-hit passes mix `get` checkouts (CGEMM pack
    // staging, f16 dequant lanes) with `take` checkouts (frequency
    // slabs); a smaller batch after warmup must register as pure reuse —
    // the capacity-keyed expansion accounting, proven at pipeline level.
    let big = ConvProblem::square(8, 4, 4, 16, 3);
    let small = ConvProblem { s: 3, ..big };
    let eng = FftConvEngine::fbfft_for(&big);
    let mut rng = Rng::new(0x5bec);
    let x_big = rng.normal_vec(big.input_len());
    let x_small = rng.normal_vec(small.input_len());
    let go_big = rng.normal_vec(big.output_len());
    let wei = rng.normal_vec(big.weight_len());
    let mut y = vec![0f32; big.output_len()];
    let mut y_small = vec![0f32; small.output_len()];
    let mut gx = vec![0f32; big.input_len()];
    let mut ws = Workspace::new();
    let mut cache = SpectrumCache::new(SpectrumPrecision::F16);

    // warmup covers the high-water marks of every role, both passes
    {
        let (spec, _) = cache.ensure(&eng, &big, &wei, 1, &mut ws);
        eng.fprop_spec_into(&big, &x_big, spec, &mut y, &mut ws);
        eng.bprop_spec_into(&big, &go_big, spec, &mut gx, &mut ws);
    }
    assert!(ws.pool.allocations > 0, "spec path must use the pool");

    ws.pool.reset_counters();
    for _ in 0..3 {
        let (spec, took) = cache.ensure(&eng, &big, &wei, 1, &mut ws);
        assert_eq!(took.as_nanos(), 0, "steady state must hit the cache");
        eng.fprop_spec_into(&big, &x_big, spec, &mut y, &mut ws);
        eng.bprop_spec_into(&big, &go_big, spec, &mut gx, &mut ws);
    }
    // the smaller batch shares the spectrum (the key omits s) and fits
    // inside warmed capacity
    {
        let (spec, took) = cache.ensure(&eng, &small, &wei, 1, &mut ws);
        assert_eq!(took.as_nanos(), 0, "spectra are batch-size agnostic");
        eng.fprop_spec_into(&small, &x_small, spec, &mut y_small,
                            &mut ws);
    }
    assert_eq!(ws.pool.allocations, 0,
               "steady-state spec pass allocated a new pool buffer");
    assert_eq!(ws.pool.expansions, 0,
               "steady-state spec pass grew a pool buffer");
    assert!(ws.pool.reuses > 0,
            "spec passes must reuse pooled buffers");

    assert_close_oracle(&y, &oracle::fprop64(&big, &x_big, &wei),
                        tolerance::frequency_f16(&big, Pass::Fprop,
                                                 eng.n_fft));
    assert_close_oracle(&y_small,
                        &oracle::fprop64(&small, &x_small, &wei),
                        tolerance::frequency_f16(&small, Pass::Fprop,
                                                 eng.n_fft));
    assert_close_oracle(&gx, &oracle::bprop64(&big, &go_big, &wei),
                        tolerance::frequency_f16(&big, Pass::Bprop,
                                                 eng.n_fft));
}

#[test]
fn pool_survives_problem_size_growth_then_stabilizes() {
    // §3.3: buffers grow to the high-water mark, then everything reuses
    let small = ConvProblem::square(2, 2, 2, 9, 3);
    let big = ConvProblem::square(4, 6, 6, 15, 3);
    let mut rng = Rng::new(0x9770);
    let eng = FftConvEngine::new(FftMode::Fbfft, 16);
    let mut ws = Workspace::new();
    for p in [&small, &big, &small, &big] {
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        let mut y = vec![0f32; p.output_len()];
        eng.fprop_into(p, &x, &wei, &mut y, &mut ws);
    }
    let allocs = ws.pool.allocations;
    let exps = ws.pool.expansions;
    for p in [&small, &big] {
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        let mut y = vec![0f32; p.output_len()];
        eng.fprop_into(p, &x, &wei, &mut y, &mut ws);
    }
    assert_eq!(ws.pool.allocations, allocs);
    assert_eq!(ws.pool.expansions, exps);
}

#[test]
fn chained_serving_steady_state_is_zero_alloc_after_first_flush() {
    // PR 8 satellite: the whole-chain flush ping-pongs activations
    // through two pooled roles, so a shard's staging pool allocates
    // exactly twice — on the first flush — and every later checkout
    // (n_layers per flush) is a reuse. The counters ride the shard
    // report, so the invariant is provable from outside the worker.
    const FLUSHES: usize = 6;
    let net = NetPlan::alexnet_small(8);
    let cap = net.batch();
    let n_layers = net.len();
    let cfg = EngineConfig::builder()
        .shards(1)
        .capacity(cap)
        .max_wait(Duration::from_millis(1))
        .default_deadline(Duration::from_secs(60))
        .warm(false)
        .build()
        .unwrap();
    let engine = ServeEngine::start(Backend::Host, net, cfg).unwrap();
    for _ in 0..FLUSHES {
        // full-capacity tickets flush immediately and alone; the
        // blocking wait serializes the flushes (constant shape)
        let t = engine.submit_images(cap, None).expect("admitted");
        let c = t.wait_timeout(Duration::from_secs(60))
            .expect("flush completes");
        assert!(c.error.is_none());
    }
    let report = engine.shutdown();
    assert_eq!(report.launches(), FLUSHES);
    assert_eq!(report.stage_allocations(), 2,
               "one heap allocation per activation role, ever");
    assert_eq!(report.stage_expansions(), 0,
               "constant flush shape never regrows a slab");
    assert_eq!(report.stage_reuses(), n_layers * FLUSHES - 2,
               "every post-warmup layer checkout is a pool reuse");
}
