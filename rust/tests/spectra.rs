//! Weight-spectrum cache tier: versioned invalidation must be
//! equivalent to rebuilding the spectrum from scratch, and the f16
//! planar slabs must conform to the testkit's `frequency_f16` budget
//! across the Table-2 matrix — not just on a hand-picked shape.

use std::time::Duration;

use fbfft_repro::conv::{ConvProblem, FftConvEngine, SpectrumCache,
                        SpectrumPrecision, Workspace};
use fbfft_repro::coordinator::Pass;
use fbfft_repro::testkit::{assert_close_oracle, cases, oracle,
                           tolerance};
use fbfft_repro::util::Rng;

/// ISSUE 6 tentpole acceptance: bumping the version and re-ensuring
/// produces exactly the output an uncached engine computes with the new
/// weights — bitwise, since f32 slabs replay the identical CGEMM.
#[test]
fn bumped_cache_matches_an_uncached_engine_bitwise() {
    let p = ConvProblem::square(4, 3, 2, 10, 3);
    let eng = FftConvEngine::fbfft_for(&p);
    let mut rng = Rng::new(0xBEEF);
    let x = rng.normal_vec(p.input_len());
    let w1 = rng.normal_vec(p.weight_len());
    let w2 = rng.normal_vec(p.weight_len());
    let mut ws = Workspace::new();
    let mut cache = SpectrumCache::new(SpectrumPrecision::F32);
    let mut y = vec![0f32; p.output_len()];

    // v1 populates; the hit replays it without touching the weights
    {
        let (spec, took) = cache.ensure(&eng, &p, &w1, 1, &mut ws);
        assert!(took > Duration::ZERO);
        eng.fprop_spec_into(&p, &x, spec, &mut y, &mut ws);
    }
    {
        let (spec, took) = cache.ensure(&eng, &p, &w1, 1, &mut ws);
        assert_eq!(took, Duration::ZERO, "same version must hit");
        eng.fprop_spec_into(&p, &x, spec, &mut y, &mut ws);
    }

    // the bump drops exactly the stale entry, and the rebuilt spectrum
    // serves the new weights as if the cache had never existed
    assert_eq!(cache.bump(&p, 2), 1, "one stale entry dropped");
    let mut y2 = vec![0f32; p.output_len()];
    {
        let (spec, took) = cache.ensure(&eng, &p, &w2, 2, &mut ws);
        assert!(took > Duration::ZERO, "post-bump ensure is a miss");
        eng.fprop_spec_into(&p, &x, spec, &mut y2, &mut ws);
    }
    let mut fresh = vec![0f32; p.output_len()];
    eng.fprop_into(&p, &x, &w2, &mut fresh, &mut Workspace::new());
    assert_eq!(y2, fresh, "f32 spec path must be bitwise the fresh pass");

    let stats = cache.stats();
    assert_eq!(stats.hits, 1);
    assert_eq!(stats.misses, 2);
    assert_eq!(stats.invalidated, 1);
}

/// A bump must not evict spectra of *other* weight shapes: only the
/// bumped problem's (f, f', kh, kw) entries older than the new version
/// go.
#[test]
fn bump_spares_other_weight_shapes() {
    let p = ConvProblem::square(2, 2, 2, 8, 3);
    let q = ConvProblem::square(2, 4, 4, 8, 3); // different weight shape
    let ep = FftConvEngine::fbfft_for(&p);
    let eq = FftConvEngine::fbfft_for(&q);
    let mut rng = Rng::new(0xD1FF);
    let wp = rng.normal_vec(p.weight_len());
    let wq = rng.normal_vec(q.weight_len());
    let mut ws = Workspace::new();
    let mut cache = SpectrumCache::new(SpectrumPrecision::F16);
    cache.ensure(&ep, &p, &wp, 1, &mut ws);
    cache.ensure(&eq, &q, &wq, 1, &mut ws);
    assert_eq!(cache.len(), 2);
    assert_eq!(cache.bump(&p, 2), 1, "only p's entry is stale");
    assert_eq!(cache.len(), 1, "q's spectrum survives the bump");
    let (_, took) = cache.ensure(&eq, &q, &wq, 1, &mut ws);
    assert_eq!(took, Duration::ZERO, "q still hits after p's bump");
}

/// Satellite 4 acceptance: f16 planar slabs stay inside the
/// `frequency_f16` tolerance model for every conformance-suite shape
/// (the adversarial set plus sampled Table-2 points), fprop and bprop —
/// the two passes that consume cached spectra.
#[test]
fn f16_slabs_conform_across_the_conformance_matrix() {
    for case in cases::conformance_suite() {
        let p = &case.problem;
        let eng = FftConvEngine::fbfft_for(p);
        let mut rng = Rng::new(case.seed);
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        let go = rng.normal_vec(p.output_len());
        let mut ws = Workspace::new();
        let mut cache = SpectrumCache::new(SpectrumPrecision::F16);

        let mut y = vec![0f32; p.output_len()];
        {
            let (spec, _) = cache.ensure(&eng, p, &wei, 1, &mut ws);
            eng.fprop_spec_into(p, &x, spec, &mut y, &mut ws);
        }
        assert_close_oracle(
            &y, &oracle::fprop64(p, &x, &wei),
            tolerance::frequency_f16(p, Pass::Fprop, eng.n_fft));

        let mut gx = vec![0f32; p.input_len()];
        {
            // bprop shares the fprop spectrum — this must be a hit
            let (spec, took) = cache.ensure(&eng, p, &wei, 1, &mut ws);
            assert_eq!(took, Duration::ZERO,
                       "{}: bprop re-transformed the weights", case.name);
            eng.bprop_spec_into(p, &go, spec, &mut gx, &mut ws);
        }
        assert_close_oracle(
            &gx, &oracle::bprop64(p, &go, &wei),
            tolerance::frequency_f16(p, Pass::Bprop, eng.n_fft));
    }
}

/// The `FBFFT_SPECTRA=f32` escape hatch stores full-precision slabs:
/// spec-path output is then bitwise the uncached pass on every
/// conformance shape, so the hatch really is "cache off, numerics-wise".
#[test]
fn f32_slabs_are_bitwise_the_uncached_pass_matrix_wide() {
    for case in cases::conformance_suite() {
        let p = &case.problem;
        let eng = FftConvEngine::fbfft_for(p);
        let mut rng = Rng::new(case.seed ^ 0xF32);
        let x = rng.normal_vec(p.input_len());
        let wei = rng.normal_vec(p.weight_len());
        let mut ws = Workspace::new();
        let mut cache = SpectrumCache::new(SpectrumPrecision::F32);
        let mut y = vec![0f32; p.output_len()];
        {
            let (spec, _) = cache.ensure(&eng, p, &wei, 1, &mut ws);
            eng.fprop_spec_into(p, &x, spec, &mut y, &mut ws);
        }
        let mut fresh = vec![0f32; p.output_len()];
        eng.fprop_into(p, &x, &wei, &mut fresh, &mut Workspace::new());
        assert_eq!(y, fresh, "{}: f32 spec path diverged", case.name);
    }
}
