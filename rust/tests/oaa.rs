//! The Overlap-and-Add tier: property tests over the tile decomposition
//! (boundary seams, the degenerate single-tile case, strided scatter)
//! plus the 5-engine OaA conformance matrix at 256²–512² and the 1-D
//! long-signal shape — sizes the full-pad fbfft path cannot even
//! construct (`MAX_N = 256`) — and the acceptance check that the
//! autotuner actually picks [`Strategy::FbfftOaA`] where the paper's
//! §6 regime analysis says it must.

use fbfft_repro::conv::{oaa, ConvProblem, FftConvEngine, FftMode,
                        OaaEngine, SpectrumPrecision};
use fbfft_repro::coordinator::{Autotuner, Pass, Strategy};
use fbfft_repro::testkit::{assert_close_oracle, cases, matrix, oracle,
                           tolerance, SuiteReport};
use fbfft_repro::util::Rng;

/// The three allocating passes of one engine against the f64 oracle,
/// each under its modelled OaA tolerance.
fn check_all_passes(p: &ConvProblem, tile: usize, seed: u64) {
    let eng = OaaEngine::for_problem(p, tile);
    let mut rng = Rng::new(seed);
    let x = rng.normal_vec(p.input_len());
    let w = rng.normal_vec(p.weight_len());
    let go = rng.normal_vec(p.output_len());
    assert_close_oracle(&eng.fprop(p, &x, &w).0,
                        &oracle::fprop64(p, &x, &w),
                        tolerance::oaa(p, Pass::Fprop, tile));
    assert_close_oracle(&eng.bprop(p, &go, &w).0,
                        &oracle::bprop64(p, &go, &w),
                        tolerance::oaa(p, Pass::Bprop, tile));
    assert_close_oracle(&eng.accgrad(p, &go, &x).0,
                        &oracle::accgrad64(p, &go, &x),
                        tolerance::oaa(p, Pass::AccGrad, tile));
}

#[test]
fn oaa_conformance_matrix() {
    let suite = cases::oaa_cases();
    // acceptance floor: a shape past the full-pad basis cap and the
    // 1-D long-signal shape are both present
    assert!(suite.len() >= 5, "suite has only {} cases", suite.len());
    assert!(suite.iter().any(|c| c.problem.h.max(c.problem.w) > 256),
            "no case beyond the fbfft full-pad cap (MAX_N = 256)");
    assert!(suite.iter().any(|c| c.problem.h == 1 || c.problem.w == 1),
            "no 1-D long-signal case");

    let report = SuiteReport {
        cases: suite
            .iter()
            .map(|c| matrix::run_case_with(c, &matrix::oaa_engine_set(c)))
            .collect(),
    };
    println!("{}", report.render());

    for (case, cr) in suite.iter().zip(&report.cases) {
        let engines = matrix::oaa_engine_set(case).len();
        assert_eq!(cr.cells.len(), engines * Pass::ALL.len(),
                   "{}: incomplete matrix row", cr.name);
    }
    assert!(report.all_ok(),
            "OaA conformance failures:\n{}", report.render());
}

#[test]
fn tile_boundaries_are_seamless_across_tile_choices() {
    // 37×41 with 3×5 kernels: the stride-1 output grid is 35×37, so
    // tile 8 leaves ragged 3- and 5-wide edge tiles, tile 16 a ragged
    // corner, and tile 30 one dominant tile plus slivers — every
    // overlap seam and edge-window shape gets exercised, and all three
    // decompositions must agree with the oracle (not just each other)
    let p = ConvProblem::new(1, 2, 3, 37, 41, 3, 5);
    for tile in [8usize, 16, 30] {
        assert!(oaa::tile_supported(tile, p.kh, p.kw));
        check_all_passes(&p, tile, 0x0AA0 + tile as u64);
    }
}

#[test]
fn single_tile_degenerates_to_full_pad_bitwise() {
    // y_ext = 46 fits in one 62-tile, so the OaA gather is the identity
    // and the sub-problem *is* the full-pad problem at the same basis
    // (64): every pass must agree with FftConvEngine bit for bit
    let p = ConvProblem::square(2, 3, 4, 48, 3);
    let tile = 62;
    let eng = OaaEngine::for_problem(&p, tile);
    assert_eq!(eng.n_fft(), 64);
    let full = FftConvEngine::new(FftMode::Fbfft, eng.n_fft());
    let mut rng = Rng::new(0xB17);
    let x = rng.normal_vec(p.input_len());
    let w = rng.normal_vec(p.weight_len());
    let go = rng.normal_vec(p.output_len());
    assert_eq!(eng.fprop(&p, &x, &w).0, full.fprop(&p, &x, &w).0);
    assert_eq!(eng.bprop(&p, &go, &w).0, full.bprop(&p, &go, &w).0);
    assert_eq!(eng.accgrad(&p, &go, &x).0, full.accgrad(&p, &go, &x).0);
}

#[test]
fn strided_fprop_matches_the_oracle() {
    // stride 2 over a 65² input: OaA tiles the stride-1 grid (63², so
    // 16-tiles leave a ragged 15-wide edge) and the scatter subsamples
    // the congruent rows/columns per tile — the part a full-pad engine
    // never exercises
    let p = ConvProblem::builder()
        .batch(2)
        .planes(3, 5)
        .hw(65, 65)
        .kernel(3, 3)
        .stride(2)
        .build();
    let tile = 16;
    let eng = OaaEngine::for_problem(&p, tile);
    let mut rng = Rng::new(0x57D2);
    let x = rng.normal_vec(p.input_len());
    let w = rng.normal_vec(p.weight_len());
    let got = eng.fprop(&p, &x, &w).0;
    let want = oracle::fprop64(&p, &x, &w);
    assert_close_oracle(&got, &want,
                        tolerance::oaa(&p, Pass::Fprop, tile));
}

#[test]
fn autotuner_selects_oaa_on_the_large_small_kernel_regime() {
    // 512² with a 3×3 kernel, steady-state serving (weight spectrum
    // pre-cached): the full-pad fbfft candidate cannot exist (512 >
    // MAX_N), the vendor sweep collapses to the single 512 basis whose
    // transforms dwarf the work, and the batch-starved time-domain
    // engines are left against the tile-batched OaA candidates — the
    // §6 regime where overlap-add is the *only* sensible frequency
    // strategy. The tuner must measure its way to it.
    let p = ConvProblem::square(1, 8, 8, 512, 3);
    let mut t = Autotuner::new();
    t.reps = 1;
    t.try_tiling = false; // kernel-sized §6 tiles are hopeless at 512²
    t.serve_spectra = Some(SpectrumPrecision::F32);
    let c = t.tune(&p, Pass::Fprop);
    assert!(matches!(c.strategy, Strategy::FbfftOaA(_)),
            "expected FbfftOaA to win the 512² k3 steady-state sweep, \
             got {:?} ({:.3} ms)", c.strategy, c.seconds * 1e3);
    let n = c.n_fft.expect("frequency strategies carry a basis");
    assert!(n <= 128, "OaA won on an oversized tile basis {n}");
}
