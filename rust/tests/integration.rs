//! Integration tests over the real artifacts directory: PJRT-loaded
//! AOT modules cross-checked against the in-tree host engines.
//!
//! These require `make artifacts` (the Python AOT pipeline) *and* a real
//! PJRT backend. When either is absent — the common case for a plain
//! `cargo test` checkout — every test here skips with an explanatory
//! message instead of failing: the host-engine tiers (`unit tests`,
//! `prop.rs`, `conformance.rs`) carry the correctness burden without
//! artifacts.

use fbfft_repro::conv::{direct, ConvProblem, FftConvEngine};
use fbfft_repro::coordinator::{Backend, EngineConfig, LayerPlan, NetPlan,
                               NetworkScheduler, Pass, ServeEngine,
                               Strategy};
use fbfft_repro::runtime::{HostTensor, Runtime};
use fbfft_repro::util::Rng;

/// Print the one shared skip message for this artifact-gated tier.
fn skip(e: &anyhow::Error) {
    eprintln!(
        "SKIP artifact-gated integration test: {e:#}\n  \
         (run the Python AOT pipeline, `python/compile/aot.py`, and \
         provide a real PJRT backend to enable this tier)");
}

/// Open the artifacts-backed runtime, or explain why this test is
/// skipping (no `artifacts/` from the AOT pipeline, or no PJRT backend).
fn rt() -> Option<Runtime> {
    match Runtime::open("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            skip(&e);
            None
        }
    }
}

/// `let Some(rt) = ... else return` with the skip message, as a macro so
/// every test body stays one line longer than before.
macro_rules! require_rt {
    () => {
        match rt() {
            Some(rt) => rt,
            None => return,
        }
    };
}

fn max_err(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn manifest_has_all_experiment_families() {
    let rt = require_rt!();
    let m = rt.manifest();
    for prefix in ["conv.quickstart.", "conv.T4.", "conv.alexnet.",
                   "conv.overfeat.", "conv.swp.", "conv.s54.",
                   "conv.tile.", "fft1d.", "fft2d.", "train."] {
        assert!(m.with_prefix(prefix).count() > 0,
                "no artifacts with prefix {prefix}");
    }
    assert!(m.entries.len() >= 200, "expected full artifact set");
}

#[test]
fn quickstart_artifacts_match_host_engine() {
    let rt = require_rt!();
    let p = ConvProblem::square(2, 4, 4, 16, 3);
    let mut rng = Rng::new(42);
    let x = rng.normal_vec(p.input_len());
    let w = rng.normal_vec(p.weight_len());
    let want = direct::fprop(&p, &x, &w);
    for strat in ["vendor", "fbfft"] {
        let (got, shape) = rt
            .execute_1f32(
                &format!("conv.quickstart.{strat}.fprop"),
                &[HostTensor::f32(x.clone(), &[2, 4, 16, 16]),
                  HostTensor::f32(w.clone(), &[4, 4, 3, 3])])
            .unwrap();
        assert_eq!(shape, vec![2, 4, 14, 14]);
        assert!(max_err(&got, &want) < 1e-3,
                "{strat} deviates from host direct engine");
    }
}

#[test]
fn pallas_pipeline_all_three_passes_match_host() {
    let rt = require_rt!();
    // T4.L4 scaled: S=8, f=f'=16, 16x16, k=7
    let e = rt.manifest().conv("T4.L4@_8", "fbfft", "fprop")
        .expect("T4.L4 artifact");
    let p = e.problem().unwrap();
    let mut rng = Rng::new(7);
    let x = rng.normal_vec(p.input_len());
    let w = rng.normal_vec(p.weight_len());
    let go = rng.normal_vec(p.output_len());
    let host = FftConvEngine::fbfft_for(&p);

    let (got, _) = rt.execute_1f32(
        "conv.T4.L4@_8.fbfft.fprop",
        &[HostTensor::f32(x.clone(), &[p.s, p.f, p.h, p.w]),
          HostTensor::f32(w.clone(), &[p.fo, p.f, p.kh, p.kw])]).unwrap();
    let (want, _) = host.fprop(&p, &x, &w);
    assert!(max_err(&got, &want) < 2e-2, "fprop mismatch");

    let (got, _) = rt.execute_1f32(
        "conv.T4.L4@_8.fbfft.bprop",
        &[HostTensor::f32(go.clone(), &[p.s, p.fo, p.yh(), p.yw()]),
          HostTensor::f32(w.clone(), &[p.fo, p.f, p.kh, p.kw])]).unwrap();
    let (want, _) = host.bprop(&p, &go, &w);
    assert!(max_err(&got, &want) < 2e-2, "bprop mismatch");

    let (got, _) = rt.execute_1f32(
        "conv.T4.L4@_8.fbfft.accgrad",
        &[HostTensor::f32(go.clone(), &[p.s, p.fo, p.yh(), p.yw()]),
          HostTensor::f32(x.clone(), &[p.s, p.f, p.h, p.w])]).unwrap();
    let (want, _) = host.accgrad(&p, &go, &x);
    assert!(max_err(&got, &want) < 5e-2, "accgrad mismatch");
}

#[test]
fn fft1d_artifact_matches_host_fbfft() {
    let rt = require_rt!();
    let n = 32usize;
    let batch = 4096usize;
    let mut rng = Rng::new(3);
    let x = rng.normal_vec(batch * n);
    let outs = rt
        .execute(&format!("fft1d.n{n}.b{batch}.fbfft"),
                 &[HostTensor::f32(x.clone(), &[batch, n])])
        .unwrap();
    let re = outs[0].as_f32().unwrap();
    let im = outs[1].as_f32().unwrap();
    let plan = fbfft_repro::fft::fbfft_host::cached(n);
    let nf = n / 2 + 1;
    let mut want = vec![fbfft_repro::fft::C32::ZERO; batch * nf];
    plan.rfft_batch(&x, n, batch, &mut want);
    for b in (0..batch).step_by(997) {
        for k in 0..nf {
            let w = want[b * nf + k];
            assert!((re[b * nf + k] - w.re).abs() < 1e-2, "re b={b} k={k}");
            assert!((im[b * nf + k] - w.im).abs() < 1e-2, "im b={b} k={k}");
        }
    }
}

#[test]
fn tiled_artifact_equals_untiled() {
    let rt = require_rt!();
    let e = rt.manifest().get("conv.tile.x57.fbfft.fprop").unwrap();
    let p = e.problem().unwrap();
    let mut rng = Rng::new(9);
    let x = rng.normal_vec(p.input_len());
    let w = rng.normal_vec(p.weight_len());
    let args = [HostTensor::f32(x, &[p.s, p.f, p.h, p.w]),
                HostTensor::f32(w, &[p.fo, p.f, p.kh, p.kw])];
    let (base, _) =
        rt.execute_1f32("conv.tile.x57.fbfft.fprop", &args).unwrap();
    for d in [8usize, 16] {
        let (tiledv, _) = rt
            .execute_1f32(&format!("conv.tile.x57.fbfft_tiled.fprop.d{d}"),
                          &args)
            .unwrap();
        assert!(max_err(&base, &tiledv) < 2e-2, "tile d={d} deviates");
    }
}

#[test]
fn train_step_reduces_loss() {
    let rt = require_rt!();
    let log = fbfft_repro::reports::trainer::train_demo(&rt, 120, 0xFEED)
        .unwrap();
    assert_eq!(log.steps, 120);
    let first10: f32 =
        log.losses[..10].iter().sum::<f32>() / 10.0;
    let last10: f32 =
        log.losses[log.steps - 10..].iter().sum::<f32>() / 10.0;
    assert!(last10 < first10 * 0.8,
            "loss did not improve: {first10} -> {last10}");
    assert!(log.losses.iter().all(|l| l.is_finite()));
}

#[test]
fn scheduler_runs_scaled_alexnet_all_passes() {
    let rt = require_rt!();
    let plans = fbfft_repro::reports::cnn::plans("alexnet", Strategy::Fbfft);
    let mut sched = NetworkScheduler::new(&rt, plans);
    sched.check_artifacts(&Pass::ALL).unwrap();
    let (f, b, a) = sched.run_all().unwrap();
    assert_eq!(f.per_layer.len(), 5);
    assert_eq!(b.per_layer.len(), 5);
    assert_eq!(a.per_layer.len(), 5);
    assert!(f.total().as_nanos() > 0);
}

#[test]
fn scheduler_fails_fast_on_missing_artifact() {
    let rt = require_rt!();
    let plans = vec![LayerPlan {
        spec: "does.not.exist".into(),
        problem: ConvProblem::square(1, 1, 1, 8, 3),
        strategy: Strategy::Fbfft,
    }];
    let sched = NetworkScheduler::new(&rt, plans);
    let err = sched.check_artifacts(&[Pass::Fprop]).unwrap_err();
    assert!(err.to_string().contains("does.not.exist"));
}

#[test]
fn service_end_to_end_on_quickstart() {
    let p = ConvProblem::square(2, 4, 4, 16, 3);
    // the legacy shim's semantics, spelled in today's API: one shard,
    // no SLA pressure (1h default deadline), no warm-up tuning
    let cfg = EngineConfig::builder()
        .shards(1)
        .capacity(2)
        .max_wait(std::time::Duration::from_millis(1))
        .default_deadline(std::time::Duration::from_secs(3600))
        .warm(false)
        .build()
        .expect("valid engine config");
    let eng = match ServeEngine::start(
        Backend::Pjrt { dir: "artifacts".into(),
                        artifact: "conv.quickstart.fbfft.fprop".into() },
        NetPlan::single(p),
        cfg,
    ) {
        Ok(eng) => eng,
        Err(e) => {
            skip(&e);
            return;
        }
    };
    let client = eng.client();
    let tickets: Vec<_> = (0..10)
        .map(|_| client.submit_images(1, None).expect("admitted"))
        .collect();
    for t in &tickets {
        let c = t.wait().expect("served");
        assert!(c.error.is_none(), "request failed: {:?}", c.error);
        assert!(c.latency.as_secs_f64() >= 0.0);
        assert!(c.batch_images <= 2);
    }
    let report = eng.shutdown();
    assert_eq!(report.requests(), 10);
    assert!(report.launches() >= 5, "batching factor <= capacity");
}

#[test]
fn runtime_rejects_wrong_shapes() {
    let rt = require_rt!();
    let err = rt
        .execute_1f32("conv.quickstart.fbfft.fprop",
                      &[HostTensor::f32(vec![0.0; 4], &[2, 2]),
                        HostTensor::f32(vec![0.0; 4], &[2, 2])])
        .unwrap_err();
    assert!(err.to_string().contains("expected shape"));
}

#[test]
fn executable_cache_compiles_once() {
    let rt = require_rt!();
    rt.executable("conv.quickstart.vendor.fprop").unwrap();
    let c1 = rt.stats().compiles;
    rt.executable("conv.quickstart.vendor.fprop").unwrap();
    assert_eq!(rt.stats().compiles, c1, "second fetch must hit the cache");
}
