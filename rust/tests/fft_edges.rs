//! Edge coverage for the FFT substrate: the paths a convolution-level
//! test can miss — Bluestein at primes, round-trip identity across the
//! whole supported size range, the real-transform/complex-transform
//! agreement, and the 2-D row–column decomposition vs the naive 2-D DFT.

use fbfft_repro::fft::bluestein::Bluestein;
use fbfft_repro::fft::fft2d::{irfft2, rfft2};
use fbfft_repro::fft::real::{irfft, rfft, rfft_len};
use fbfft_repro::fft::{is_smooth, naive_dft, plan, C32, Direction};
use fbfft_repro::testkit::{oracle, tolerance};
use fbfft_repro::util::Rng;

fn rand_complex(rng: &mut Rng, n: usize) -> Vec<C32> {
    (0..n).map(|_| C32::new(rng.normal(), rng.normal())).collect()
}

#[test]
fn bluestein_matches_naive_dft_at_primes() {
    // primes outside the radix set {2,3,5,7}: the pure Bluestein path
    for n in [11usize, 13, 17, 19, 23, 29, 31, 37, 41, 53, 61, 101, 127,
              251] {
        assert!(!is_smooth(n), "{n} must exercise Bluestein");
        let mut rng = Rng::new(0xB1 + n as u64);
        let x = rand_complex(&mut rng, n);
        let bs = Bluestein::new(n);
        let tol = tolerance::fft_abs(n);
        let want = naive_dft(&x, false);
        for (k, (g, w)) in
            bs.transform(&x, false).iter().zip(&want).enumerate()
        {
            assert!((*g - *w).abs() < tol,
                    "n={n} k={k}: {g:?} vs {w:?} (tol {tol})");
        }
        // and the planner dispatches these sizes to Bluestein
        assert_eq!(plan::cached(n).algorithm_name(), "bluestein");
    }
}

#[test]
fn forward_inverse_round_trip_sizes_8_to_256() {
    // every size in the paper's transform range, smooth or not
    for n in 8usize..=256 {
        let mut rng = Rng::new(0x27 + n as u64);
        let x = rand_complex(&mut rng, n);
        let p = plan::cached(n);
        let f = p.transform(&x, Direction::Forward);
        let back = p.inverse_normalized(&f);
        let tol = tolerance::fft_abs(n);
        for (i, (b, o)) in back.iter().zip(&x).enumerate() {
            assert!((*b - *o).abs() < tol,
                    "n={n} i={i}: {b:?} vs {o:?} (tol {tol})");
        }
    }
}

#[test]
fn rfft_agrees_with_complex_fft_on_real_input() {
    // even (packed half-size path), odd, prime and smooth sizes
    for n in [8usize, 9, 11, 12, 16, 21, 25, 27, 31, 32, 49, 64, 97, 100,
              128, 243, 256] {
        let mut rng = Rng::new(0x3E + n as u64);
        let x = rng.normal_vec(n);
        let half = rfft(&x, n);
        assert_eq!(half.len(), rfft_len(n));
        let z: Vec<C32> = x.iter().map(|v| C32::new(*v, 0.0)).collect();
        let full = plan::cached(n).transform(&z, Direction::Forward);
        let tol = tolerance::fft_abs(n);
        for (k, (g, w)) in half.iter().zip(&full).enumerate() {
            assert!((*g - *w).abs() < tol,
                    "n={n} k={k}: {g:?} vs {w:?} (tol {tol})");
        }
        // and C2R inverts R2C
        let back = irfft(&half, n);
        for (i, (b, o)) in back.iter().zip(&x).enumerate() {
            assert!((b - o).abs() < tol, "n={n} i={i}: {b} vs {o}");
        }
    }
}

#[test]
fn rfft2_matches_naive_2d_dft() {
    // row–column decomposition vs the oracle's direct 2-D definition,
    // on pow2, smooth non-pow2 and prime bases, square and rectangular
    for (h, w, n) in [(5usize, 6usize, 8usize), (7, 7, 8), (5, 5, 12),
                      (6, 4, 10), (9, 9, 13), (8, 8, 8)] {
        let mut rng = Rng::new(0x2D + (h * 31 + w * 7 + n) as u64);
        let img = rng.normal_vec(h * w);
        let f = rfft2(&img, h, w, n);
        let nf = rfft_len(n);
        // bigger constant than the 1-D budget: h·w terms per bin
        let tol = 4.0 * tolerance::fft_abs(n);
        for kh in 0..n {
            for kw in 0..nf {
                let (re, im) = oracle::dft2_bin64(&img, h, w, n, kh, kw);
                let got = f[kh * nf + kw];
                assert!((got.re as f64 - re).abs() < tol as f64
                        && (got.im as f64 - im).abs() < tol as f64,
                        "h={h} w={w} n={n} bin=({kh},{kw}): \
                         {got:?} vs ({re}, {im}) (tol {tol})");
            }
        }
        // round trip with clip back to the unpadded image
        let back = irfft2(&f, n, h, w);
        for (i, (b, o)) in back.iter().zip(&img).enumerate() {
            assert!((b - o).abs() < tol, "h={h} w={w} n={n} i={i}");
        }
    }
}

#[test]
fn rfft_len_is_half_spectrum() {
    assert_eq!(rfft_len(8), 5);
    assert_eq!(rfft_len(9), 5);
    assert_eq!(rfft_len(256), 129);
    assert_eq!(rfft_len(1), 1);
}
