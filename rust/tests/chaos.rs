//! Chaos tier: deterministic fault injection against the sharded
//! serving engine. Every test scripts a `FaultPlan` (the same hook the
//! CI chaos-smoke bench drives via `--faults`) and asserts the ISSUE 7
//! robustness contract: exactly-once completions (success **or**
//! error), supervised restarts with a circuit breaker, graceful
//! degradation to the direct fallback, and cold-start recovery from a
//! corrupt persisted cache. PR 8 adds the `layer<j>` fault qualifier:
//! a panic scripted at a mid-chain position must fail exactly the
//! in-flight batch with that chain position attributed in the
//! [`ServeFailure::ShardPanic`] it resolves with. Host backend only —
//! no artifacts needed.

use std::collections::HashSet;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use fbfft_repro::conv::ConvProblem;
use fbfft_repro::coordinator::batcher::BatcherConfig;
use fbfft_repro::coordinator::service::{Backend, Completion,
                                        EngineConfig, ServeEngine,
                                        ServeFailure, ServeRequest};
use fbfft_repro::coordinator::{NetPlan, Strategy};
use fbfft_repro::testkit::faults::FaultPlan;

fn cfg(cap: usize, wait_ms: u64) -> BatcherConfig {
    BatcherConfig { capacity: cap,
                    max_wait: Duration::from_millis(wait_ms) }
}

fn plan(spec: &str) -> Option<Arc<FaultPlan>> {
    Some(Arc::new(FaultPlan::parse(spec).expect("fault spec parses")))
}

/// Wait (bounded) for the supervisor to flip a shard's alive bit.
fn await_dead(engine: &ServeEngine, shard: usize) {
    let t0 = Instant::now();
    while engine.health()[shard].is_alive() {
        assert!(t0.elapsed() < Duration::from_secs(10),
                "shard {shard} never circuit-broke");
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// ISSUE 7 acceptance core: a scripted panic mid-flush fails exactly
/// the in-flight batch (error completions, never silence), the shard
/// restarts, and every admitted request still resolves exactly once.
#[test]
fn injected_panic_mid_flush_preserves_exactly_once() {
    const REQUESTS: usize = 40;
    let p = ConvProblem::square(4, 1, 1, 8, 3);
    let engine = ServeEngine::start_host(
        p,
        EngineConfig {
            shards: 2,
            batcher: cfg(4, 1),
            default_deadline: Duration::from_secs(60),
            warm: false,
            restart_backoff: Duration::from_millis(1),
            faults: plan("shard0:panic@1"),
            ..Default::default()
        })
        .unwrap();
    let (tx, rx) = mpsc::channel::<Completion>();
    for id in 0..REQUESTS as u64 {
        assert!(engine
            .submit(ServeRequest {
                id,
                images: 1 + (id % 3) as usize,
                deadline: None,
                reply: tx.clone(),
            })
            .is_ok());
    }
    drop(tx);
    let mut seen = HashSet::new();
    let mut failed = 0usize;
    for _ in 0..REQUESTS {
        let c = rx.recv_timeout(Duration::from_secs(30))
            .expect("every admitted request completes, success or error");
        assert!(seen.insert(c.id), "duplicate completion {}", c.id);
        if let Some(err) = c.error {
            // a flush-level injected panic hits before the layer chain
            // starts, so no chain position is attributed
            assert_eq!(err, ServeFailure::ShardPanic { layer: None });
            assert!(!c.deadline_met);
            failed += 1;
        }
    }
    assert_eq!(seen.len(), REQUESTS);
    assert!(failed >= 1, "the panicked flush must fail its batch");
    assert!(rx.recv_timeout(Duration::from_millis(50)).is_err(),
            "no extra completions after exactly-once delivery");

    let report = engine.shutdown();
    assert_eq!(report.requests(), REQUESTS);
    assert_eq!(report.requests_failed(), failed);
    assert_eq!(report.requests_completed(), REQUESTS - failed);
    assert_eq!(report.shard_restarts(), 1, "one panic, one respawn");
    assert!(report.faults_injected >= 1);
    assert_eq!(report.circuit_broken(), 0,
               "a single panic must not trip the breaker");
    for s in &report.shards {
        assert_eq!(s.requests_completed + s.requests_failed, s.requests,
                   "shard {}: completed+failed must equal requests",
                   s.shard);
        assert_eq!(s.launches,
                   s.flushes_full + s.flushes_timeout + s.flushes_drain,
                   "shard {}: flush ledger stays balanced under faults",
                   s.shard);
    }
    let s0 = &report.shards[0];
    assert_eq!(s0.restarts, 1);
    assert!(s0.requests_failed >= 1);
    assert!(s0.last_error.as_deref().unwrap_or("")
              .contains("injected shard panic"),
            "last_error records the panic payload: {:?}", s0.last_error);
}

/// Two consecutive scripted panics trip the circuit breaker: the shard
/// is marked dead, its queue is drained with error completions, and the
/// three survivors keep serving within SLA.
#[test]
fn circuit_breaker_reroutes_to_surviving_shards() {
    const CAP: usize = 4;
    let p = ConvProblem::square(CAP, 1, 1, 8, 3);
    let engine = ServeEngine::start_host(
        p,
        EngineConfig {
            shards: 4,
            batcher: cfg(CAP, 1),
            default_deadline: Duration::from_secs(60),
            warm: false,
            restart_backoff: Duration::from_millis(1),
            max_consecutive_failures: 2,
            faults: plan("shard0:panic@1,shard0:panic@2"),
            ..Default::default()
        })
        .unwrap();
    let (tx, rx) = mpsc::channel::<Completion>();
    // serialized full-capacity requests: each flushes alone and the
    // rotating least-loaded tie-break walks the shards round-robin, so
    // shard 0 sees its two scripted panics within the first rounds
    let serve_one = |id: u64| -> Completion {
        assert!(engine
            .submit(ServeRequest {
                id,
                images: CAP,
                deadline: None,
                reply: tx.clone(),
            })
            .is_ok(), "survivors keep the engine available");
        rx.recv_timeout(Duration::from_secs(30))
            .expect("request resolves")
    };
    let mut failed = 0usize;
    for id in 0..8u64 {
        let c = serve_one(id);
        if c.error.is_some() {
            assert_eq!(c.error,
                       Some(ServeFailure::ShardPanic { layer: None }));
            failed += 1;
        }
    }
    assert_eq!(failed, 2, "both scripted panics fail their flush");
    await_dead(&engine, 0);
    assert!(!engine.health()[0].is_alive());
    assert_eq!(engine.health()[0].restarts(), 1,
               "first panic respawns, second trips the breaker");
    // post-break traffic: only survivors, all within the (generous) SLA
    for id in 100..112u64 {
        let c = serve_one(id);
        assert!(c.error.is_none(), "survivors serve cleanly");
        assert!(c.shard != 0, "no traffic to the dead shard");
        assert!(c.deadline_met, "survivors meet the SLA");
    }
    drop(tx);

    let report = engine.shutdown();
    assert_eq!(report.requests(), 20);
    assert_eq!(report.requests_failed(), 2);
    assert_eq!(report.requests_completed(), 18);
    assert_eq!(report.circuit_broken(), 1);
    assert_eq!(report.faults_injected, 2);
    let s0 = &report.shards[0];
    assert!(s0.circuit_broken, "shard 0 tripped the breaker");
    assert_eq!(s0.restarts, 1);
    assert_eq!(s0.requests_failed, 2);
    for s in &report.shards {
        assert_eq!(s.requests_completed + s.requests_failed, s.requests);
        assert_eq!(s.launches,
                   s.flushes_full + s.flushes_timeout + s.flushes_drain);
    }
}

/// With every shard dead, `submit` returns `Err(Unavailable)` instead
/// of panicking — the satellite contract replacing the old
/// `.expect("serve shard worker gone")`.
#[test]
fn submit_reports_unavailable_when_all_shards_are_dead() {
    const CAP: usize = 4;
    let p = ConvProblem::square(CAP, 1, 1, 8, 3);
    let engine = ServeEngine::start_host(
        p,
        EngineConfig {
            shards: 1,
            batcher: cfg(CAP, 1),
            default_deadline: Duration::from_secs(60),
            warm: false,
            max_consecutive_failures: 1,
            faults: plan("shard0:panic@1"),
            ..Default::default()
        })
        .unwrap();
    let (tx, rx) = mpsc::channel::<Completion>();
    assert!(engine
        .submit(ServeRequest { id: 1, images: CAP, deadline: None,
                               reply: tx.clone() })
        .is_ok());
    let c = rx.recv_timeout(Duration::from_secs(30)).expect("resolves");
    assert_eq!(c.error, Some(ServeFailure::ShardPanic { layer: None }));
    await_dead(&engine, 0);
    assert_eq!(engine
                   .submit(ServeRequest { id: 2, images: 1,
                                          deadline: None, reply: tx })
                   .unwrap_err(),
               ServeFailure::Unavailable);
    let report = engine.shutdown();
    assert_eq!(report.rejected_unavailable, 1);
    assert_eq!(report.requests(), 1);
    assert_eq!(report.requests_failed(), 1);
    assert_eq!(report.circuit_broken(), 1);
    assert_eq!(report.shards[0].restarts, 0,
               "max_consecutive_failures=1 breaks without a respawn");
}

/// A scripted staging-pool allocation failure unwinds the flush, fails
/// the batch, and the respawned shard (fresh pool) serves on.
#[test]
fn alloc_failure_fails_batch_then_recovers() {
    const CAP: usize = 4;
    let p = ConvProblem::square(CAP, 1, 1, 8, 3);
    let engine = ServeEngine::start_host(
        p,
        EngineConfig {
            shards: 1,
            batcher: cfg(CAP, 1),
            default_deadline: Duration::from_secs(60),
            warm: false,
            restart_backoff: Duration::from_millis(1),
            faults: plan("shard0:alloc_fail@1"),
            ..Default::default()
        })
        .unwrap();
    let (tx, rx) = mpsc::channel::<Completion>();
    let serve_one = |id: u64| -> Completion {
        assert!(engine
            .submit(ServeRequest { id, images: CAP, deadline: None,
                                   reply: tx.clone() })
            .is_ok());
        rx.recv_timeout(Duration::from_secs(30)).expect("resolves")
    };
    let first = serve_one(1);
    // the poisoned checkout panics inside layer 0 of the chain, so the
    // failure carries the chain position it unwound from
    assert_eq!(first.error,
               Some(ServeFailure::ShardPanic { layer: Some(0) }),
               "the poisoned checkout fails its flush");
    for id in 2..5u64 {
        let c = serve_one(id);
        assert!(c.error.is_none(), "fresh pool serves after respawn");
    }
    drop(tx);
    let report = engine.shutdown();
    let s0 = &report.shards[0];
    assert_eq!(s0.restarts, 1);
    assert_eq!(s0.requests_failed, 1);
    assert_eq!(s0.requests_completed, 3);
    assert!(report.faults_injected >= 1);
    assert!(s0.last_error.as_deref().unwrap_or("")
              .contains("allocation failure"),
            "{:?}", s0.last_error);
}

/// A scripted `corrupt_load` truncates the persisted strategy cache on
/// open: the engine must cold-start (warning counted, zero entries,
/// re-tune) instead of refusing to boot.
#[test]
fn corrupt_cache_load_degrades_to_cold_start() {
    let tmp = std::env::temp_dir().join("fbfft_chaos_tune_test.json");
    std::fs::remove_file(&tmp).ok();
    const CAP: usize = 4;
    let p = ConvProblem::square(CAP, 1, 1, 8, 3);
    let engine_cfg = |faults: Option<Arc<FaultPlan>>| EngineConfig {
        shards: 1,
        batcher: cfg(CAP, 1),
        default_deadline: Duration::from_secs(60),
        warm: false,
        tuner_path: Some(tmp.clone()),
        faults,
        ..Default::default()
    };
    let serve_one = |engine: &ServeEngine| {
        let (tx, rx) = mpsc::channel::<Completion>();
        assert!(engine
            .submit(ServeRequest { id: 7, images: CAP, deadline: None,
                                   reply: tx })
            .is_ok());
        let c = rx.recv_timeout(Duration::from_secs(30))
            .expect("request served");
        assert!(c.error.is_none());
    };
    // seed a healthy persisted cache
    let engine = ServeEngine::start_host(p, engine_cfg(None)).unwrap();
    serve_one(&engine);
    let seeded = engine.shutdown();
    assert!(seeded.cache.tunes > 0);
    assert!(tmp.exists(), "cache persisted");
    // reopen with the load fault scripted: cold start, not a crash
    let engine =
        ServeEngine::start_host(p, engine_cfg(plan("corrupt_load@1")))
            .unwrap();
    assert!(engine.cache().stats().load_warnings >= 1,
            "corrupted text must be counted, not expected away");
    serve_one(&engine);
    let report = engine.shutdown();
    assert!(report.cache.load_warnings >= 1);
    assert!(report.cache.tunes > 0,
            "cold start re-tunes the served shape");
    assert!(report.faults_injected >= 1);
    std::fs::remove_file(&tmp).ok();
}

/// A scripted non-finite frequency-domain output demotes the problem's
/// strategy to the direct fallback for the cooldown window: the client
/// sees clean successes while the report counts degraded flushes.
#[test]
fn nonfinite_output_demotes_to_direct_fallback() {
    const CAP: usize = 8;
    let p = ConvProblem::square(CAP, 2, 2, 8, 3);
    let engine = ServeEngine::start_host(
        p,
        EngineConfig {
            shards: 1,
            batcher: cfg(CAP, 1),
            default_deadline: Duration::from_secs(60),
            warm: false,
            force_strategy: Some(Strategy::Fbfft),
            degrade_cooldown: Duration::from_secs(30),
            faults: plan("shard0:nonfinite@1"),
            ..Default::default()
        })
        .unwrap();
    let (tx, rx) = mpsc::channel::<Completion>();
    for id in 0..2u64 {
        // full-capacity requests flush immediately and alone; the
        // blocking recv serializes the two flushes
        assert!(engine
            .submit(ServeRequest { id, images: CAP, deadline: None,
                                   reply: tx.clone() })
            .is_ok());
        let c = rx.recv_timeout(Duration::from_secs(30))
            .expect("flush completes");
        assert!(c.error.is_none(),
                "degradation is invisible to the client");
    }
    drop(tx);
    let report = engine.shutdown();
    let s0 = &report.shards[0];
    assert_eq!(report.requests(), 2);
    assert_eq!(report.requests_failed(), 0);
    assert_eq!(s0.restarts, 0, "degradation never respawns the shard");
    assert_eq!(report.degraded_flushes(), 2,
               "the triggering flush plus the cooldown-window flush");
    assert_eq!(report.launch_errors(), 1,
               "only the triggering flush counts as a launch error");
    assert_eq!(report.faults_injected, 1);
    // the demoted window never touched the frequency path again
    assert_eq!(report.spectra_misses(), 1,
               "one weight FFT before the NaN was caught");
    assert_eq!(report.spectra_hits(), 0);
}

/// PR 8 tentpole acceptance: a panic scripted at chain position 1 of a
/// three-layer net fails exactly the in-flight batch with the layer
/// index recorded, the shard restarts, and the chain serves on.
#[test]
fn mid_chain_panic_records_layer_and_preserves_exactly_once() {
    let net = NetPlan::alexnet_small(8);
    let cap = net.batch();
    let engine = ServeEngine::start(
        Backend::Host,
        net,
        EngineConfig {
            shards: 1,
            batcher: cfg(cap, 1),
            default_deadline: Duration::from_secs(60),
            warm: false,
            restart_backoff: Duration::from_millis(1),
            faults: plan("shard0:layer1:panic@1"),
            ..Default::default()
        })
        .unwrap();
    let (tx, rx) = mpsc::channel::<Completion>();
    let serve_one = |id: u64| -> Completion {
        assert!(engine
            .submit(ServeRequest { id, images: cap, deadline: None,
                                   reply: tx.clone() })
            .is_ok());
        rx.recv_timeout(Duration::from_secs(30)).expect("resolves")
    };
    // conv1 of the first flush runs clean; the scripted fault unwinds
    // the chain from conv2 and the completion attributes layer 1
    let first = serve_one(1);
    assert_eq!(first.error,
               Some(ServeFailure::ShardPanic { layer: Some(1) }),
               "mid-chain panic records the chain position it hit");
    for id in 2..5u64 {
        let c = serve_one(id);
        assert!(c.error.is_none(),
                "the respawned shard serves the full chain");
    }
    assert!(rx.recv_timeout(Duration::from_millis(50)).is_err(),
            "no extra completions after exactly-once delivery");
    drop(tx);
    let report = engine.shutdown();
    assert_eq!(report.requests(), 4);
    assert_eq!(report.requests_failed(), 1);
    assert_eq!(report.requests_completed(), 3);
    assert_eq!(report.shards[0].restarts, 1);
    assert!(report.faults_injected >= 1);
    assert!(report.shards[0].last_error.as_deref().unwrap_or("")
              .contains("layer 1"),
            "last_error names the chain position: {:?}",
            report.shards[0].last_error);
    // the per-layer ledger saw conv1 execute once more than conv3: the
    // panicked flush recorded conv1's latency before unwinding at
    // conv2, and the failure is charged to conv2's error count
    let layers = report.layer_stats();
    assert_eq!(layers.len(), 3);
    assert_eq!(layers[1].launch_errors, 1,
               "the panic is charged to the layer it unwound from");
    assert_eq!(layers[0].latency.len(), 4);
    assert_eq!(layers[1].latency.len(), 3);
    assert_eq!(layers[2].latency.len(), 3);
}
